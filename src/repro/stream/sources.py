"""Trace sources: where a task stream comes from.

The simulator's historical entry point materializes ONE fixed-horizon
workload tensor up front. A :class:`TraceSource` instead yields the
workload in arrival-ordered *blocks* of bounded size, so the streaming
driver (:mod:`repro.stream.driver`) can ingest, simulate and retire tasks
window by window with bounded memory — the trace-driven operating mode the
paper's platform runs in (production analytics traces, not a horizon).

Three sources ship:

  - :class:`SyntheticSource` — wraps :func:`repro.core.synthesizer.
    synthesize_block` with per-block folded RNG keys and an arrival-clock
    carry, so streamed synthesis is *bit-identical* to materializing every
    block at once (the streamed-vs-oneshot parity gate rests on this);
  - :class:`SpanSource` — ingests the OTel-style JSONL span export
    (:mod:`repro.obs.spans`) back into a workload plus a replay
    :class:`~repro.ops.scenario.CompiledScenario`, so yesterday's export
    re-simulates under a different scheduler/controller (replay-what-if);
  - :class:`WorkloadManager` — the pull-driven ingestion buffer between a
    source and the driver (the "constantly running workload generator" of
    the reference implementations, pull-based so the consumer paces it):
    it pulls blocks on demand, keeps per-row columns, and serves exact
    arrival-windowed slices.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Protocol, \
    runtime_checkable

import numpy as np

from repro.core import model as M
from repro.core.workload import MAX_TASKS


@runtime_checkable
class TraceSource(Protocol):
    """A re-iterable stream of arrival-ordered workload blocks.

    ``blocks()`` must return a FRESH iterator each call (so a parity
    reference can re-read the same stream), arrivals must be globally
    non-decreasing across the concatenated blocks, and every block must
    share ``max_tasks``. Unbounded sources simply never stop yielding —
    the consumer bounds them (window budget / ``max_blocks``)."""

    name: str

    def blocks(self) -> Iterator[M.Workload]: ...


# ---------------------------------------------------------------------------
# synthetic stream
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SyntheticSource:
    """Unbounded (or bounded) stream of synthesized workload blocks.

    Block ``b`` draws with key ``fold_in(PRNGKey(seed), b)`` and continues
    the clustered interarrival clock from the previous block's last
    arrival. Draw shapes depend only on ``block_size``, never on any
    horizon, so the content of block ``b`` is a pure function of
    ``(params, seed, block_size, b, t0_b)`` — two consumers reading the
    same source see identical tensors no matter how they window them.

    ``n_blocks=None`` and ``until_s=None`` together make the source
    unbounded; ``until_s`` stops yielding once a block *starts* at or past
    that clock (the block that crosses it is still yielded whole).
    """

    params: object
    platform: Optional[M.PlatformConfig] = None
    seed: int = 0
    block_size: int = 256
    n_blocks: Optional[int] = None
    until_s: Optional[float] = None
    interarrival_factor: float = 1.0
    name: str = "synthetic"

    def blocks(self) -> Iterator[M.Workload]:
        import jax

        from repro.core.synthesizer import synthesize_block
        platform = self.platform or M.PlatformConfig()
        root = jax.random.PRNGKey(self.seed)
        t0, b = 0.0, 0
        while self.n_blocks is None or b < self.n_blocks:
            if self.until_s is not None and t0 >= self.until_s:
                return
            wl = synthesize_block(self.params, jax.random.fold_in(root, b),
                                  self.block_size, t0=t0, platform=platform,
                                  interarrival_factor=self.interarrival_factor)
            t0 = float(wl.arrival[-1])
            b += 1
            yield wl


def materialize(source: TraceSource,
                max_blocks: Optional[int] = None) -> M.Workload:
    """Concatenate a (bounded) source into one plain workload — how the
    non-streaming engines run a ``source``-driven spec, and the workload
    half of the streamed-vs-oneshot parity reference. Unbounded sources
    must pass ``max_blocks``."""
    from repro.core.runtime import _concat_workloads
    out = None
    for b, wl in enumerate(source.blocks()):
        if max_blocks is not None and b >= max_blocks:
            break
        out = wl if out is None else _concat_workloads(out, wl)
    if out is None:
        raise ValueError(f"source {source.name!r} yielded no blocks")
    return out


# ---------------------------------------------------------------------------
# ingestion buffer
# ---------------------------------------------------------------------------

class WorkloadManager:
    """Pull-driven ingestion buffer between a :class:`TraceSource` and the
    streaming driver.

    ``on_block(wl, block_idx) -> dict of [n, ...] arrays`` turns each
    pulled block into per-row columns (the driver's hook compiles the
    block's failure draws here, so attempt tensors ride the rows and any
    later windowing slices them consistently); the default just exposes
    the raw workload columns. ``take_until(t)`` returns every buffered or
    pullable row whose **float32** arrival is <= ``t`` — the same cast the
    engine clock uses, so a window boundary can never split the driver's
    view from the engine's.
    """

    def __init__(self, source: TraceSource,
                 on_block: Optional[Callable[[M.Workload, int],
                                             Dict[str, np.ndarray]]] = None):
        self._it = source.blocks()
        self._on_block = on_block or _raw_columns
        self._pending: List[Dict[str, np.ndarray]] = []
        self._exhausted = False
        self.n_blocks = 0
        self.n_rows = 0

    @property
    def exhausted(self) -> bool:
        """True once the source stopped AND the buffer drained."""
        return self._exhausted and not self._pending

    @property
    def last_buffered_arrival(self) -> float:
        return (float(self._pending[-1]["arrival"][-1])
                if self._pending else -np.inf)

    def _pull(self) -> bool:
        try:
            wl = next(self._it)
        except StopIteration:
            self._exhausted = True
            return False
        cols = dict(self._on_block(wl, self.n_blocks))
        if "arrival" not in cols:
            cols["arrival"] = np.asarray(wl.arrival, np.float64)
        self._pending.append(cols)
        self.n_blocks += 1
        self.n_rows += int(cols["arrival"].shape[0])
        return True

    def stop(self) -> None:
        """Stop ingesting: the source is treated as exhausted (buffered
        rows still drain) — how a driver bounds an unbounded source."""
        self._exhausted = True

    def take_until(self, t: Optional[float]) -> List[Dict[str, np.ndarray]]:
        """Consume every row with ``float32(arrival) <= t`` (``None`` =
        everything the source has left — only valid on bounded sources).
        Pulls blocks until one ends past ``t``, then splits at the exact
        f32 boundary; returns the consumed column dicts (possibly empty).
        """
        while not self._exhausted and (
                t is None
                or np.float32(self.last_buffered_arrival) <= np.float32(t)):
            if not self._pull():
                break
        out: List[Dict[str, np.ndarray]] = []
        while self._pending:
            seg = self._pending[0]
            arr32 = np.asarray(seg["arrival"], np.float64).astype(np.float32)
            k = (arr32.shape[0] if t is None
                 else int(np.searchsorted(arr32, np.float32(t), side="right")))
            if k == 0:
                break
            if k == arr32.shape[0]:
                out.append(self._pending.pop(0))
            else:
                out.append({f: v[:k] for f, v in seg.items()})
                self._pending[0] = {f: v[k:] for f, v in seg.items()}
                break
        return out


def _raw_columns(wl: M.Workload, block_idx: int) -> Dict[str, np.ndarray]:
    return dict(arrival=np.asarray(wl.arrival, np.float64),
                n_tasks=np.asarray(wl.n_tasks, np.int32),
                task_type=np.asarray(wl.task_type, np.int32),
                task_res=np.asarray(wl.task_res, np.int32),
                exec_time=np.asarray(wl.exec_time, np.float64),
                read_bytes=np.asarray(wl.read_bytes, np.float64),
                write_bytes=np.asarray(wl.write_bytes, np.float64),
                framework=np.asarray(wl.framework, np.int32),
                priority=np.asarray(wl.priority, np.float32))


# ---------------------------------------------------------------------------
# span-export replay
# ---------------------------------------------------------------------------

class SpanSource:
    """Rebuild a workload (and a replay scenario) from a JSONL span export.

    The PR 6 span schema records, per task, its pipeline's arrival, its
    resource, its executed attempt count, and (with per-attempt recording)
    every attempt's exact ``(start, end)`` slot-hold interval. That is
    sufficient to reconstruct an *equivalent* workload: per-attempt service
    times are the observed intervals verbatim (a failing attempt held its
    slot for exactly that long, whatever ``fail_holds_frac`` produced it),
    IO bytes fold into the observed durations (zero-IO reconstruction — the
    repo's exact-parity configuration), and re-queue delays reproduce from
    the same :class:`~repro.ops.failures.RetryPolicy` backoff. Re-simulating
    on the same platform/policy then reproduces the original attempt
    intervals exactly (tested); swap the schedule, controller, or admission
    policy and the same observed demand replays under the what-if
    (``replay_trace.py`` example).

    Tasks exported stranded (never started) carry no duration; they replay
    with a nominal service and are reported in ``n_approximate``.
    """

    def __init__(self, spans, platform: Optional[M.PlatformConfig] = None,
                 name: str = "replay"):
        from repro.obs.spans import read_spans_jsonl
        if isinstance(spans, (str, bytes)):
            spans = read_spans_jsonl(spans)
        self.platform = platform or M.PlatformConfig()
        self.name = name
        self.n_approximate = 0
        self._build(spans)

    # -- reconstruction -----------------------------------------------------
    def _build(self, spans) -> None:
        # the exporter writes canonical M.RESOURCE_NAMES (plus the res<i>
        # overflow form); accept the replay platform's own names too
        res_idx = {n: i for i, n in enumerate(M.RESOURCE_NAMES)}
        res_idx.update({f"res{i}": i for i in range(
            len(self.platform.resources))})
        res_idx.update({r.name: i
                        for i, r in enumerate(self.platform.resources)})
        type_idx = {n: i for i, n in enumerate(M.TASK_TYPE_NAMES)}
        pipes, tasks, atts = {}, {}, {}
        for s in spans:
            a = s.get("attributes", {})
            if s["kind"] == "pipeline":
                pipes[a["pipeline"]] = float(s["start_s"])
            elif s["kind"] == "task":
                tasks[(a["pipeline"], a["task_pos"])] = (
                    s["name"].partition(":")[2], a.get("resource"),
                    int(a.get("attempts", 1)), s["start_s"], s["end_s"])
            elif s["kind"] == "attempt":
                atts[(a["pipeline"], a["task_pos"], a["attempt"])] = (
                    s["start_s"], s["end_s"])
        if not pipes:
            raise ValueError("no pipeline spans in the export")
        # rows in arrival order (original pids break ties), as a synthesized
        # workload would order them
        pids = sorted(pipes, key=lambda p: (pipes[p], p))
        self.pipeline_ids = np.asarray(pids, np.int64)
        row_of = {p: i for i, p in enumerate(pids)}
        n = len(pids)
        arrival = np.asarray([pipes[p] for p in pids], np.float64)
        n_tasks = np.zeros(n, np.int32)
        tt = np.full((n, MAX_TASKS), -1, np.int32)
        tres = np.zeros((n, MAX_TASKS), np.int32)
        exec_t = np.zeros((n, MAX_TASKS), np.float64)
        attempts = np.ones((n, MAX_TASKS), np.int64)
        A = max([a for (_, _, a) in atts] or [0]) + 1
        att_svc = np.zeros((n, MAX_TASKS, A), np.float64)
        for (pid, pos), (tname, rname, n_att, t0, t1) in tasks.items():
            i = row_of[pid]
            n_tasks[i] = max(n_tasks[i], pos + 1)
            ttype = type_idx.get(tname, M.TRAIN)
            tt[i, pos] = ttype
            tres[i, pos] = (res_idx[rname] if rname in res_idx
                            else int(self.platform.route(
                                np.asarray([ttype]))[0]))
            attempts[i, pos] = n_att
            durs = []
            for a in range(n_att):
                iv = atts.get((pid, pos, a))
                if iv is not None and iv[0] is not None and iv[1] is not None:
                    durs.append(float(iv[1]) - float(iv[0]))
            if not durs:
                # no attempt spans: a clean single attempt runs start->end;
                # multi-attempt legacy exports (or stranded tasks) can only
                # replay approximately
                if t0 is not None and t1 is not None and n_att <= 1:
                    durs = [float(t1) - float(t0)]
                else:
                    durs = [((float(t1) - float(t0)) / max(n_att, 1))
                            if t0 is not None and t1 is not None else 1e-2]
                    self.n_approximate += 1
            exec_t[i, pos] = durs[0]
            pad = durs + [durs[-1]] * (A - len(durs))
            att_svc[i, pos, :] = pad[:A]
        zeros2 = np.zeros((n, MAX_TASKS))
        self.workload = M.Workload(
            arrival=arrival, n_tasks=n_tasks, task_type=tt, task_res=tres,
            exec_time=exec_t, read_bytes=zeros2, write_bytes=zeros2.copy(),
            framework=np.zeros(n, np.int32),
            priority=np.zeros(n, np.float32),
            model_perf=np.zeros(n, np.float32),
            model_size=np.zeros(n, np.float32),
            model_clever=np.zeros(n, np.float32))
        self._attempts = attempts
        self._att_svc = att_svc if A > 1 else None

    # -- TraceSource --------------------------------------------------------
    def blocks(self) -> Iterator[M.Workload]:
        yield self.workload

    # -- replay -------------------------------------------------------------
    def scenario(self, schedule=None, controller=None, backoff=None,
                 horizon_s: Optional[float] = None):
        """The replay :class:`~repro.ops.scenario.CompiledScenario`: the
        *observed* attempt counts and per-attempt slot-hold times, under an
        exchangeable schedule/controller (the what-if knobs). ``backoff``
        must match the original run's retry policy for re-queue delays to
        reproduce (default: :class:`~repro.ops.failures.RetryPolicy`'s).
        ``controller`` is a :class:`~repro.ops.capacity.ReactiveController`
        (compiled against this source's platform) or a pre-compiled
        ControllerParams tensor."""
        from repro.ops.capacity import static_schedule
        from repro.ops.failures import RetryPolicy
        from repro.ops.scenario import CompiledScenario
        if controller is not None and hasattr(controller, "compile"):
            if horizon_s is None:
                raise ValueError("pass horizon_s to compile a controller "
                                 "for the replay")
            controller = controller.compile(self.platform.capacities,
                                            horizon_s)
        return CompiledScenario(
            schedule=(schedule if schedule is not None
                      else static_schedule(self.platform.capacities)),
            attempts=self._attempts,
            backoff=tuple(backoff) if backoff is not None
            else RetryPolicy().backoff,
            attempt_service=self._att_svc,
            controller=controller)

    def remap_pipelines(self, rec):
        """Map a replay's row-indexed ``rec.pipeline`` back to the original
        export's pipeline ids (rows were re-ordered by arrival), so replayed
        records compare key-for-key against the original export."""
        import dataclasses as _dc
        return _dc.replace(rec, pipeline=self.pipeline_ids[
            np.asarray(rec.pipeline, np.int64)])
