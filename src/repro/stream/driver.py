"""Windowed streaming simulation driver.

``stream_simulate`` runs an arrival-ordered task stream (any
:class:`repro.stream.sources.TraceSource`) through the batched JAX engine
in *horizon windows*: ingest every row arriving up to the next boundary,
run the wave loop with the boundary as the engine's ``time_budget`` (PR 8's
windowed-cut hook — the loop provably stops before any wave past the
guard), download the carry, retire DONE pipelines out of the working set,
append the next window's rows, and resume. The working set is therefore
sized by the *live* backlog, not the stream length: memory stays bounded
at millions of tasks while the queue/controller/fleet/probe state — every
scalar, tick cursor and recording buffer — rides the engine's resume carry
verbatim across each boundary.

Bit-parity argument (twin-tested in ``tests/test_stream.py`` and gated at
0.0 drift in ``benchmarks/stream_bench.py``):

  - a row absent from window ``k`` has ``float32(arrival) > boundary_k``
    (the ingestion buffer cuts on the same f32 cast as the engine clock),
    and the loop stops before any wave with ``t_star > boundary_k`` — so
    introducing the row in window ``k+1`` is invisible to every wave it
    could have touched;
  - retired rows are DONE (inert forever; their records are extracted at
    retirement);
  - the working layout is ``[retained exo rows | new exo rows | retraining
    pool | padding]`` with retained/new rows each in ascending global-id
    order and every new id greater than every retained id: all pairwise
    row orders match the one-shot layout, so the admission tie-break
    (a relative-order sort) decides identically, and the pool block stays
    contiguous at a per-window ``pool_base``;
  - fresh rows enter with exactly the engine's own initial per-row state
    (NOT_ARRIVED, ``t_next = f32(arrival)``, NaN time tensors), and
    padding rows carry ``arrival = inf``: they never arrive, never count
    as a pending event, and — exactly like latent retraining-pool rows —
    do not keep the wave loop alive, so the drain window exits at the
    same instant the one-shot run does (tail controller ticks included).

Synthesis for window ``k+1`` (block draws + per-block failure compiles +
host staging) overlaps window ``k``'s device step when ``overlap=True``;
the constant pool/pad blocks are device-resident from window 0.

``oneshot_reference`` materializes the SAME stream — identical per-block
RNG draws, identical pool/fleet/probe compiles — into one
``vdes.simulate_ensemble`` call: the parity oracle, and the fixed-horizon
baseline the benchmarks compare sustained tasks/s against.
"""
from __future__ import annotations

import dataclasses
import time
from types import SimpleNamespace
from typing import Callable, Dict, List, Optional

import numpy as np

import jax

from repro.core import model as M
from repro.core import trace, vdes
from repro.core.batching import (batch_trace, stack_fleets, stack_probes,
                                 stack_scenarios)
from repro.core.compaction import ROW_STATE_KEYS, _bucket
from repro.core.des import (CTRL_INF, POLICY_FIFO, ctrl_tick_bound,
                            unpack_ctrl_actions, unpack_fleet_actions)
from repro.stream.sources import TraceSource, WorkloadManager

_DONE = 3            # vdes._DONE
_POSITIONAL = ("arrival", "n_tasks", "task_res", "service", "priority",
               "capacities")

#: host-side content columns kept per live row (what record extraction and
#: the next window's input tensors are assembled from)
_CONTENT = ("gid", "arrival", "n_tasks", "task_type", "task_res", "service",
            "read_bytes", "write_bytes", "framework", "priority", "attempts")


def _block_seed(seed: int, block_idx: int) -> int:
    """Per-block failure-draw seed — the streamed and one-shot paths MUST
    fold identically for attempts/attempt_service parity."""
    return int(seed) + 7919 * int(block_idx)

_POOL_SALT = 0x9E37    # pool rows compile as their own pseudo-block


@dataclasses.dataclass
class StreamResult:
    """What a streamed run produces. ``records`` is None when a ``sink``
    consumed them incrementally (unbounded runs); the operational
    timelines (controller actions, fleet tensors, probe matrix) come from
    the final carry — the recording buffers ride every boundary verbatim,
    so they are exactly the one-shot run's."""

    records: Optional[trace.TaskRecords]
    summary: Dict
    n_windows: int
    n_blocks: int
    n_pipelines: int            # exogenous pipelines ingested
    n_task_rows: int            # task records emitted (incl. retraining)
    waves: int
    peak_rows: int              # bounded working width (memory proxy)
    peak_live: int              # largest live (unretired) row count
    wall_s: float
    ingest_s: float             # host-side synthesis + failure-draw time
    ctrl_times: Optional[np.ndarray] = None
    ctrl_caps: Optional[np.ndarray] = None
    fleet_cols: Optional[Dict] = None
    probe_times: Optional[np.ndarray] = None
    probe_vals: Optional[np.ndarray] = None


class _StreamPlan:
    """Everything shared between the windowed driver and the one-shot
    reference: the schedule/controller/backoff resolution, the per-block
    failure compiles (same folded seeds), the fleet/pool/probe compiles,
    and the static engine arguments. One plan, two executions — the basis
    of the parity gate."""

    def __init__(self, platform, policy, scenario, fleet, trigger, probe,
                 horizon_s, seed, params, admission_sort):
        from repro.obs.probes import compile_probe
        from repro.ops.capacity import static_schedule
        from repro.ops.failures import RetryPolicy
        from repro.ops.scenario import CompiledScenario

        self.platform = platform or M.PlatformConfig()
        self.policy = int(policy)
        self.horizon_s = float(horizon_s)
        self.seed = int(seed)
        self.params = params
        self.admission_sort = admission_sort
        self.fleet_spec, self.trigger_spec = fleet, trigger
        self.caps = np.asarray(self.platform.capacities, np.int64)

        self.scenario = None            # ops.scenario.Scenario (or None)
        self.replay = None              # pre-compiled replay scenario
        if scenario is None:
            self.schedule = static_schedule(self.platform.capacities)
            self.controller = None
            self.backoff = RetryPolicy().backoff
            self.holds_frac = 1.0
            self.a_stat, self.has_asv = 1, False
        elif hasattr(scenario, "compile_schedule"):     # a Scenario spec
            self.scenario = scenario
            self.schedule = scenario.compile_schedule(
                self.platform, self.horizon_s, seed=self.seed,
                policy=self.policy)
            self.controller = (scenario.controller.compile(
                self.platform.capacities, self.horizon_s)
                if scenario.controller is not None else None)
            fm = scenario.failures
            self.backoff = (fm.retry.backoff if fm is not None
                            else RetryPolicy().backoff)
            self.holds_frac = (float(fm.fail_holds_frac)
                               if fm is not None else 1.0)
            self.a_stat = (fm.retry.max_retries + 1) if fm is not None else 1
            self.has_asv = bool(fm is not None and fm.resample_service)
        else:                                           # CompiledScenario
            self.replay = scenario
            self.schedule = scenario.schedule
            self.controller = scenario.controller
            self.backoff = scenario.backoff
            self.holds_frac = float(scenario.fail_holds_frac)
            asv = scenario.attempt_service
            self.a_stat = max(int(np.max(scenario.attempts)),
                              asv.shape[2] if asv is not None else 1)
            self.has_asv = asv is not None
            self._replay_off = 0
        self.n_attempt_slots = self.a_stat if self.a_stat > 1 else None
        self.n_ctrl_slots = (ctrl_tick_bound(self.controller) or None
                             if self.controller is not None else None)

        self.probe = None
        if probe is not None:
            n_models = fleet.n_models if fleet is not None else 0
            self.probe = compile_probe(probe, self.horizon_s,
                                       n_models=n_models)
        self.n_probe_slots = self.probe.n_ticks if self.probe else None
        self._CompiledScenario = CompiledScenario

    # -- per-block failure draws -------------------------------------------
    def block_attempts(self, wl: M.Workload, block_idx: int):
        """``(attempts [n, T] i64, attempt_service [n, T, A] | None)`` for
        one block — folded seeds, so any two consumers of the same source
        draw identically."""
        if self.scenario is not None:
            comp = self.scenario.compile(
                wl, self.platform, self.horizon_s,
                seed=_block_seed(self.seed, block_idx), policy=self.policy,
                schedule=self.schedule)
            return np.asarray(comp.attempts, np.int64), comp.attempt_service
        if self.replay is not None:
            off = self._replay_off
            self._replay_off = off + wl.n
            att = np.asarray(self.replay.attempts[off:off + wl.n], np.int64)
            asv = (self.replay.attempt_service[off:off + wl.n]
                   if self.has_asv else None)
            return att, asv
        return np.ones(wl.task_type.shape, np.int64), None

    def on_block(self, gid0: int):
        """The :class:`WorkloadManager` hook: raw columns + service +
        failure draws + global pipeline ids."""
        counter = [gid0]

        def hook(wl: M.Workload, block_idx: int) -> Dict[str, np.ndarray]:
            att, asv = self.block_attempts(wl, block_idx)
            cols = dict(
                gid=np.arange(counter[0], counter[0] + wl.n, dtype=np.int64),
                arrival=np.asarray(wl.arrival, np.float64),
                n_tasks=np.asarray(wl.n_tasks, np.int32),
                task_type=np.asarray(wl.task_type, np.int32),
                task_res=np.asarray(wl.task_res, np.int32),
                service=np.asarray(
                    wl.service_time(self.platform.datastore), np.float64),
                read_bytes=np.asarray(wl.read_bytes, np.float64),
                write_bytes=np.asarray(wl.write_bytes, np.float64),
                framework=np.asarray(wl.framework, np.int32),
                priority=np.asarray(wl.priority, np.float32),
                attempts=att)
            if self.has_asv:
                cols["att_svc"] = np.asarray(asv, np.float64)
            counter[0] += wl.n
            return cols
        return hook

    # -- fleet / retraining pool -------------------------------------------
    def compile_fleet(self, wl: M.Workload):
        """``(CompiledFleet, pool content columns)`` — pool draws depend
        only on (trigger, platform, horizon, seed, params), so compiling
        against any workload of the stream yields the same pool rows the
        one-shot reference appends."""
        from repro.core.runtime import TriggerSpec
        from repro.ops.scenario import compile_fleet
        trig = (self.trigger_spec if self.trigger_spec is not None
                else TriggerSpec())
        cf, ext = compile_fleet(self.fleet_spec, trig, wl, self.platform,
                                self.horizon_s, seed=self.seed,
                                params=self.params)
        n0, P = wl.n, cf.n_pool
        svc = np.asarray(ext.service_time(self.platform.datastore),
                         np.float64)[n0:]
        if self.scenario is not None:
            comp = self.scenario.compile(
                _rows_workload(ext, n0), self.platform, self.horizon_s,
                seed=_block_seed(self.seed, _POOL_SALT), policy=self.policy,
                schedule=self.schedule)
            att = np.asarray(comp.attempts, np.int64)
            asv = comp.attempt_service
        else:
            att = np.ones((P, ext.max_tasks), np.int64)
            asv = None
        pool = dict(
            arrival=np.asarray(ext.arrival, np.float64)[n0:],
            n_tasks=np.asarray(ext.n_tasks, np.int32)[n0:],
            task_type=np.asarray(ext.task_type, np.int32)[n0:],
            task_res=np.asarray(ext.task_res, np.int32)[n0:],
            service=svc,
            read_bytes=np.asarray(ext.read_bytes, np.float64)[n0:],
            write_bytes=np.asarray(ext.write_bytes, np.float64)[n0:],
            framework=np.asarray(ext.framework, np.int32)[n0:],
            priority=np.asarray(ext.priority, np.float32)[n0:],
            attempts=att)
        if self.has_asv:
            pool["att_svc"] = np.asarray(asv, np.float64)
        return cf, pool

    # -- engine kwargs ------------------------------------------------------
    def scenario_kwargs(self, attempts, att_svc, services, n_max):
        """The schedule/attempt/controller kwargs for one ensemble call,
        via the tested batching stacker — with the per-window attempt-slot
        and controller-slot statics REPLACED by the plan's global ones, so
        every window (and the reference) shares one compiled signature."""
        comp = self._CompiledScenario(
            schedule=self.schedule, attempts=attempts, backoff=self.backoff,
            attempt_service=att_svc, controller=self.controller,
            fail_holds_frac=self.holds_frac)
        kw = stack_scenarios([comp], n_max, self.horizon_s,
                             services=[services], record_attempts=True,
                             record_ctrl=True)
        kw.pop("n_attempt_slots", None)
        kw.pop("n_ctrl_slots", None)
        return kw

    def statics(self) -> Dict:
        return dict(n_attempt_slots=self.n_attempt_slots,
                    admission_sort=self.admission_sort,
                    n_ctrl_slots=self.n_ctrl_slots,
                    n_probe_slots=self.n_probe_slots)


def _rows_workload(wl: M.Workload, lo: int) -> M.Workload:
    """Row-slice a workload (dataclass fields only)."""
    cols = {f.name: (v[lo:] if isinstance(v := getattr(wl, f.name),
                                          np.ndarray) else v)
            for f in dataclasses.fields(M.Workload)}
    return M.Workload(**cols)


def _cat(parts: List[np.ndarray]) -> np.ndarray:
    return parts[0] if len(parts) == 1 else np.concatenate(parts)


def _merge(buf: Dict, segs: List[Dict]) -> Dict:
    if not segs:
        return buf
    return {k: _cat([buf[k]] + [s[k] for s in segs]) if buf[k].size
            else _cat([s[k] for s in segs]) for k in buf}


def _take(buf: Dict, idx: np.ndarray) -> Dict:
    return {k: v[idx] for k, v in buf.items()}


def _empty_buf(T: int, A: int, has_asv: bool) -> Dict[str, np.ndarray]:
    buf = dict(gid=np.zeros(0, np.int64), arrival=np.zeros(0, np.float64),
               n_tasks=np.zeros(0, np.int32),
               task_type=np.zeros((0, T), np.int32),
               task_res=np.zeros((0, T), np.int32),
               service=np.zeros((0, T), np.float64),
               read_bytes=np.zeros((0, T), np.float64),
               write_bytes=np.zeros((0, T), np.float64),
               framework=np.zeros(0, np.int32),
               priority=np.zeros(0, np.float32),
               attempts=np.ones((0, T), np.int64))
    if has_asv:
        buf["att_svc"] = np.zeros((0, T, A), np.float64)
    return buf


def _extract_records(content: Dict, st: Dict, row_idx: np.ndarray,
                     gids: np.ndarray, caps: np.ndarray,
                     arrival: Optional[np.ndarray] = None
                     ) -> trace.TaskRecords:
    """Records for the given working-set rows, straight through the ONE
    flattener every engine uses — pipeline ids remapped to global ids.
    ``arrival`` overrides the content arrivals (retraining-pool activation
    times; NaN rows are latent and drop out exactly like the one-shot
    path's)."""
    sl = lambda k: np.asarray(st[k][0][row_idx], np.float64)
    tr = M.SimTrace(
        start=sl("start"), finish=sl("finish"), ready=sl("ready"),
        n_tasks=content["n_tasks"].astype(np.int64),
        task_res=content["task_res"], task_type=content["task_type"],
        arrival=(arrival if arrival is not None else content["arrival"]),
        capacities=caps,
        attempts=np.asarray(st["att_out"][0][row_idx], np.int64),
        completed=np.asarray(st["phase"][0][row_idx] == _DONE),
        att_start=sl("att_start") if "att_start" in st else None,
        att_finish=sl("att_finish") if "att_finish" in st else None)
    wl_view = SimpleNamespace(read_bytes=content["read_bytes"],
                              write_bytes=content["write_bytes"],
                              framework=content["framework"])
    rec = trace.flatten_trace(tr, wl_view)
    rec.pipeline = np.asarray(gids, np.int64)[rec.pipeline]
    return rec


def _sort_records(rec: trace.TaskRecords) -> trace.TaskRecords:
    """Rows in (pipeline, task_pos) order — retirement order varies with
    the windowing, the one-shot flattener's doesn't."""
    order = np.lexsort((rec.task_pos, rec.pipeline))
    cols = {f.name: (v[order] if (v := getattr(rec, f.name)) is not None
                     else None)
            for f in dataclasses.fields(trace.TaskRecords)}
    return trace.TaskRecords(**cols)


def _fresh_rows(key: str, proto: np.ndarray, n: int, arr32: np.ndarray,
                done: bool = False) -> np.ndarray:
    """A fresh row's engine state, exactly as ``vdes`` initializes it.
    ``done=True`` builds *padding* rows: DONE with an inf event time, so
    they neither admit, nor fire events, nor keep the wave loop alive —
    indistinguishable from rows that finished long ago."""
    shape = (1, n) + proto.shape[2:]
    if key == "phase" and done:
        return np.full(shape, _DONE, proto.dtype)
    if key == "t_next":
        return arr32[None, :].astype(proto.dtype)
    if key in ("start", "finish", "ready", "att_start", "att_finish"):
        return np.full(shape, np.nan, proto.dtype)
    return np.zeros(shape, proto.dtype)     # phases, indices, counters


def stream_simulate(
        source: TraceSource,
        platform: Optional[M.PlatformConfig] = None,
        *,
        policy: int = POLICY_FIFO,
        scenario=None,
        fleet=None,
        trigger=None,
        probe=None,
        horizon_s: float = 7 * 86400.0,
        window_s: Optional[float] = None,
        seed: int = 0,
        params=None,
        max_blocks: Optional[int] = None,
        overlap: bool = True,
        min_rows: int = 64,
        admission_sort: str = "fused",
        sink: Optional[Callable[[trace.TaskRecords], None]] = None,
        plan_out: Optional[list] = None) -> StreamResult:
    """Stream a :class:`TraceSource` through the batched engine in arrival
    windows of ``window_s`` (default ``horizon_s / 8``), bit-identical to
    materializing the whole stream into one ``simulate_ensemble`` call
    (:func:`oneshot_reference`).

    ``horizon_s`` bounds the *operational* grids (capacity schedule,
    controller / trigger / probe ticks), exactly as it does on the
    one-shot path — the task stream itself may run arbitrarily far past it
    (``max_blocks`` bounds an unbounded source; ``sink`` consumes each
    retired window's :class:`TaskRecords` so nothing accumulates).
    ``overlap=False`` disables the synthesis/transfer pipelining (the
    benchmark contrast). ``plan_out`` (a list) receives the internal plan
    for white-box tests."""
    t_wall = time.perf_counter()
    plan = _StreamPlan(platform, policy, scenario, fleet, trigger, probe,
                       horizon_s, seed, params, admission_sort)
    if plan_out is not None:
        plan_out.append(plan)
    window_s = float(window_s if window_s is not None else horizon_s / 8.0)
    if window_s <= 0:
        raise ValueError(f"window_s must be > 0, got {window_s}")

    ingest_s = [0.0]
    wm = WorkloadManager(source, on_block=plan.on_block(0))

    def take(bound):
        t0 = time.perf_counter()
        if max_blocks is not None and wm.n_blocks >= max_blocks:
            wm.stop()
        segs = wm.take_until(bound)
        ingest_s[0] += time.perf_counter() - t0
        return segs

    # ---- window 0 ingest (the fleet pool compiles off the first block)
    first = take(np.float32(window_s))
    cf, pool = None, None
    if fleet is not None:
        t0 = time.perf_counter()
        blocks_it = source.blocks()
        cf, pool = plan.compile_fleet(next(iter(blocks_it)))
        ingest_s[0] += time.perf_counter() - t0
    P = cf.n_pool if cf is not None else 0

    probe_kw = stack_probes([plan.probe], [cf]) if plan.probe else {}
    probe_kw.pop("n_probe_slots", None)
    fleet_kw = stack_fleets([cf], n_max=0) if cf is not None else {}
    statics = plan.statics()
    caps = plan.caps

    # content template dims from the first rows seen
    from repro.core.workload import MAX_TASKS
    T = (first[0]["task_type"].shape[1] if first
         else (pool["task_type"].shape[1] if pool is not None else MAX_TASKS))
    if not first and pool is None and wm.exhausted:
        raise ValueError(f"source {source.name!r} yielded no rows")
    buf = _merge(_empty_buf(T, plan.a_stat, plan.has_asv), first)

    recs: List[trace.TaskRecords] = []
    n_rows_emitted = [0]

    def emit(rec: trace.TaskRecords):
        n_rows_emitted[0] += int(rec.pipeline.shape[0])
        (sink if sink is not None else recs.append)(rec)

    W = 0
    k = 0
    peak_live = 0
    waves = 0
    st = None                    # downloaded carry from the last window
    keep_idx = None              # retained-row indices into the last layout
    prev_pool_off = 0
    pending_new = 0              # rows appended since the last layout
    final_exo_rows = final_gids = None
    capacities_row = np.asarray(plan.caps, np.int32)[None, :]

    while True:
        n_exo = int(buf["gid"].shape[0])
        peak_live = max(peak_live, n_exo)
        last = wm.exhausted
        need = n_exo + P
        # monotone power-of-two width: the jit signature changes only on
        # the (log-bounded) bucket growths, never window-to-window
        W = max(W, _bucket(need, min_rows))
        pads = W - need
        guard = (np.float32(CTRL_INF) if last
                 else np.float32((k + 1) * window_s))

        # ---- input tensors [1, W, ...]: [exo | pool | pad]
        def col(key, pad_val, dtype):
            parts = [buf[key]]
            if pool is not None:
                parts.append(pool[key])
            out = _cat(parts)
            if pads:
                pad_shape = (pads,) + out.shape[1:]
                out = np.concatenate(
                    [out, np.full(pad_shape, pad_val, out.dtype)])
            return out.astype(dtype)[None]

        # inert pads: arrival = inf rows never arrive and never keep the
        # loop alive (identical to latent pool rows), so every window —
        # the drain included — exits exactly where the one-shot loop does
        arrival32 = col("arrival", np.inf, np.float32)
        inputs = dict(
            arrival=arrival32,
            n_tasks=col("n_tasks", 1, np.int32),
            task_res=col("task_res", 0, np.int32),
            service=col("service", 0.0, np.float32),
            priority=col("priority", 0.0, np.float32),
            capacities=capacities_row)
        att = _cat([buf["attempts"]] + ([pool["attempts"]]
                                        if pool is not None else []))
        asv = (_cat([buf["att_svc"]] + ([pool["att_svc"]]
                                        if pool is not None else []))
               if plan.has_asv else None)
        svc = _cat([buf["service"]] + ([pool["service"]]
                                       if pool is not None else []))
        inputs.update(plan.scenario_kwargs(att, asv, svc, W))
        if cf is not None:
            inputs.update(fleet_kw)
            inputs["pool_base"] = np.asarray([n_exo], np.int32)
        inputs.update(probe_kw)

        # ---- resume carry: retained rows + fresh rows + pool + inert pads
        pad32 = np.full(pads, np.inf, np.float32)
        if st is None:
            # canonical init state via a zero-wave call (the compaction
            # pattern): after this, EVERY window — the first included —
            # resumes with one shared jit signature
            init = vdes.simulate_ensemble(
                *(inputs[k_] for k_ in _POSITIONAL), plan.policy,
                **{k_: v for k_, v in inputs.items()
                   if k_ not in _POSITIONAL},
                **statics, wave_budget=np.zeros(1, np.int32),
                return_state=True)
            resume = jax.device_get(init["state"])
            if pads:
                phase = np.array(resume["phase"])
                phase[:, need:] = _DONE
                resume["phase"] = phase
        else:
            n_new = pending_new
            new32 = arrival32[0, n_exo - n_new:n_exo] if n_new else None
            resume = {}
            for key, v in st.items():
                if key not in ROW_STATE_KEYS:
                    resume[key] = v
                    continue
                parts = [v[:, keep_idx]]
                if n_new:
                    parts.append(_fresh_rows(key, v, n_new, new32))
                parts.append(v[:, prev_pool_off:prev_pool_off + P])
                if pads:
                    parts.append(_fresh_rows(key, v, pads, pad32,
                                             done=True))
                resume[key] = np.concatenate(parts, axis=1)

        res = vdes.simulate_ensemble(
            *(inputs[k_] for k_ in _POSITIONAL), plan.policy,
            **{k_: v for k_, v in inputs.items() if k_ not in _POSITIONAL},
            **statics, resume=resume,
            time_budget=np.asarray([guard], np.float32), return_state=True)

        # ---- overlap: window k+1's synthesis + failure draws + staging
        segs = []
        if not last:
            if overlap:
                segs = take(np.float32((k + 2) * window_s))
        st = jax.device_get(res["state"])
        if not last and not overlap:
            segs = take(np.float32((k + 2) * window_s))

        k += 1
        waves = int(st["wave"][0])
        exo_done = np.asarray(st["phase"][0][:n_exo] == _DONE)
        if last:
            final_exo_rows = np.arange(n_exo)
            final_gids = buf["gid"]
            if n_exo:
                emit(_extract_records(buf, st, final_exo_rows, final_gids,
                                      plan.caps))
            if P:
                # pool pipeline ids follow ALL exogenous ids, exactly like
                # the one-shot extended workload's layout
                pool_gids = int(wm.n_rows) + np.arange(P)
                emit(_extract_records(
                    pool, st, n_exo + np.arange(P), pool_gids, plan.caps,
                    arrival=np.asarray(st["pool_arr"][0], np.float64)))
            break

        retired = np.flatnonzero(exo_done)
        if retired.size:
            emit(_extract_records(_take(buf, retired), st, retired,
                                 buf["gid"][retired], plan.caps))
        keep_idx = np.flatnonzero(~exo_done)
        prev_pool_off = n_exo
        buf = _take(buf, keep_idx)
        pending_new = sum(int(s["gid"].shape[0]) for s in segs)
        buf = _merge(buf, segs)

    # ---- result assembly --------------------------------------------------
    records = None
    summary: Dict = {}
    if sink is None and recs:
        records = _sort_records(trace.concat_records(recs))
        summary = trace.summarize(
            records, plan.caps, plan.horizon_s, schedule=plan.schedule,
            cost_rates=plan.platform.cost_rates,
            slo=plan.scenario.slo if plan.scenario is not None else None)
    ctrl_times = ctrl_caps = None
    if "ctrl_act" in st:
        ctrl_times, ctrl_caps = unpack_ctrl_actions(st["ctrl_act"][0],
                                                    st["ctrl_n"][0])
    fleet_cols = None
    if cf is not None and "fleet_perf" in st:
        ft, fk, fm = unpack_fleet_actions(st["fleet_act"][0],
                                          st["fleet_n"][0])
        fleet_cols = dict(
            fleet_perf=np.asarray(st["fleet_perf"][0], np.float64),
            fleet_stale=np.asarray(st["fleet_stale"][0], np.float64),
            fleet_ticks=np.asarray(cf.tick_times, np.float64),
            fleet_times=ft, fleet_kind=fk, fleet_model=fm,
            pool_arr=np.asarray(st["pool_arr"][0], np.float64),
            pool_model=np.asarray(st["pool_model"][0], np.int64))
    probe_times = probe_vals = None
    if plan.probe is not None and "probe_vals" in st:
        probe_times = np.asarray(plan.probe.times, np.float64)
        probe_vals = np.asarray(
            st["probe_vals"][0][:plan.probe.n_ticks], np.float64)

    wall = time.perf_counter() - t_wall
    summary.update(n_windows=k, n_blocks=wm.n_blocks, waves=waves,
                   peak_rows=W, wall_s=wall)
    return StreamResult(
        records=records, summary=summary, n_windows=k, n_blocks=wm.n_blocks,
        n_pipelines=wm.n_rows, n_task_rows=n_rows_emitted[0], waves=waves,
        peak_rows=W, peak_live=peak_live + P, wall_s=wall,
        ingest_s=ingest_s[0], ctrl_times=ctrl_times, ctrl_caps=ctrl_caps,
        fleet_cols=fleet_cols, probe_times=probe_times,
        probe_vals=probe_vals)


# ---------------------------------------------------------------------------
# one-shot reference (the parity oracle)
# ---------------------------------------------------------------------------

def oneshot_reference(
        source: TraceSource,
        platform: Optional[M.PlatformConfig] = None,
        *,
        policy: int = POLICY_FIFO,
        scenario=None, fleet=None, trigger=None, probe=None,
        horizon_s: float = 7 * 86400.0, seed: int = 0, params=None,
        max_blocks: Optional[int] = None,
        admission_sort: str = "fused") -> Dict:
    """Materialize the ENTIRE stream — identical per-block draws to the
    windowed driver — into one ``vdes.simulate_ensemble`` call. Returns
    the sorted records plus the operational timelines, keyed like
    :class:`StreamResult` (plus ``wall_s`` for the fixed-horizon baseline
    wall and ``workload`` for inspection)."""
    from repro.core.runtime import _concat_workloads

    t0 = time.perf_counter()
    plan = _StreamPlan(platform, policy, scenario, fleet, trigger, probe,
                       horizon_s, seed, params, admission_sort)
    wls, atts, asvs = [], [], []
    for b, wl in enumerate(source.blocks()):
        if max_blocks is not None and b >= max_blocks:
            break
        att, asv = plan.block_attempts(wl, b)
        wls.append(wl)
        atts.append(att)
        if plan.has_asv:
            asvs.append(np.asarray(asv, np.float64))
    exo = wls[0]
    for w in wls[1:]:
        exo = _concat_workloads(exo, w)

    cf = None
    wl_ext = exo
    if fleet is not None:
        from repro.core.runtime import TriggerSpec
        from repro.ops.scenario import compile_fleet
        trig = trigger if trigger is not None else TriggerSpec()
        cf, wl_ext = compile_fleet(fleet, trig, exo, plan.platform,
                                   plan.horizon_s, seed=plan.seed,
                                   params=params)
        if plan.scenario is not None:
            comp = plan.scenario.compile(
                _rows_workload(wl_ext, exo.n), plan.platform, plan.horizon_s,
                seed=_block_seed(plan.seed, _POOL_SALT), policy=plan.policy,
                schedule=plan.schedule)
            atts.append(np.asarray(comp.attempts, np.int64))
            if plan.has_asv:
                asvs.append(np.asarray(comp.attempt_service, np.float64))
        else:
            atts.append(np.ones((wl_ext.n - exo.n, exo.max_tasks), np.int64))
            if plan.has_asv:
                asvs.append(np.repeat(np.asarray(
                    wl_ext.service_time(plan.platform.datastore),
                    np.float64)[exo.n:, :, None], plan.a_stat, -1))

    N = wl_ext.n
    svc = np.asarray(wl_ext.service_time(plan.platform.datastore),
                     np.float64)
    inputs = dict(
        arrival=np.asarray(wl_ext.arrival, np.float64
                           ).astype(np.float32)[None],
        n_tasks=np.asarray(wl_ext.n_tasks, np.int32)[None],
        task_res=np.asarray(wl_ext.task_res, np.int32)[None],
        service=svc.astype(np.float32)[None],
        priority=np.asarray(wl_ext.priority, np.float32)[None],
        capacities=np.asarray(plan.caps, np.int32)[None])
    inputs.update(plan.scenario_kwargs(
        np.concatenate(atts), np.concatenate(asvs) if plan.has_asv else None,
        svc, N))
    if cf is not None:
        inputs.update(stack_fleets([cf], n_max=N))
    if plan.probe is not None:
        pkw = stack_probes([plan.probe], [cf])
        pkw.pop("n_probe_slots", None)
        inputs.update(pkw)

    out = vdes.simulate_ensemble(
        *(inputs[k_] for k_ in _POSITIONAL), plan.policy,
        **{k_: v for k_, v in inputs.items() if k_ not in _POSITIONAL},
        **plan.statics())
    out = {k_: np.asarray(v) for k_, v in out.items()}
    tr = batch_trace(out, 0, wl_ext, plan.caps, with_scenario=True,
                     fleet=cf, probe=plan.probe)
    rec = trace.flatten_trace(tr, wl_ext)
    fleet_cols = None
    if cf is not None:
        fleet_cols = dict(
            fleet_perf=np.asarray(tr.fleet_perf, np.float64),
            fleet_stale=np.asarray(tr.fleet_stale, np.float64),
            fleet_ticks=np.asarray(cf.tick_times, np.float64),
            fleet_times=np.asarray(tr.fleet_times, np.float64),
            fleet_kind=np.asarray(tr.fleet_kind, np.int64),
            fleet_model=np.asarray(tr.fleet_model, np.int64),
            pool_arr=np.asarray(out["pool_arr"][0][:cf.n_pool], np.float64),
            pool_model=np.asarray(out["pool_model"][0][:cf.n_pool],
                                  np.int64))
    return dict(records=_sort_records(rec), trace=tr, workload=wl_ext,
                ctrl_times=tr.ctrl_times, ctrl_caps=tr.ctrl_caps,
                fleet_cols=fleet_cols,
                probe_times=(np.asarray(plan.probe.times, np.float64)
                             if plan.probe is not None else None),
                probe_vals=(np.asarray(tr.probe_vals, np.float64)
                            if plan.probe is not None else None),
                wall_s=time.perf_counter() - t0,
                summary=trace.summarize(
                    _sort_records(rec), plan.caps, plan.horizon_s,
                    schedule=plan.schedule,
                    cost_rates=plan.platform.cost_rates))


# ---------------------------------------------------------------------------
# parity metric
# ---------------------------------------------------------------------------

def _nan_drift(a, b) -> float:
    """Max |a - b| with NaN==NaN; shape mismatch or one-sided NaN = inf."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    if a.shape != b.shape:
        return float("inf")
    if a.size == 0:
        return 0.0
    both_nan = np.isnan(a) & np.isnan(b)
    d = np.abs(a - b)
    d[both_nan] = 0.0
    if np.isnan(d).any():       # NaN on exactly one side
        return float("inf")
    return float(np.max(d))


def _pad_att(v: Optional[np.ndarray], width: int,
             n: int) -> Optional[np.ndarray]:
    if v is None:
        return np.full((n, width), np.nan)
    if v.shape[1] < width:
        v = np.pad(v, ((0, 0), (0, width - v.shape[1])),
                   constant_values=np.nan)
    return v


def parity_drift(sr: StreamResult, ref: Dict) -> float:
    """Max |streamed - oneshot| over every comparable tensor: the task
    records (timestamps, attempts, per-attempt windows), the realized
    controller timeline, the fleet drift/staleness/action tensors, and the
    probe matrix. 0.0 = bit parity. The wave counter is excluded by
    design (padding rows execute extra far-future waves in the drain
    window)."""
    a, b = sr.records, ref["records"]
    drift = 0.0
    if a.pipeline.shape != b.pipeline.shape:
        return float("inf")
    for f in ("pipeline", "task_pos", "task_type", "resource", "ready",
              "start", "finish", "read_bytes", "write_bytes", "framework",
              "attempts", "arrival", "pipeline_done"):
        drift = max(drift, _nan_drift(getattr(a, f), getattr(b, f)))
    wa = [v.shape[1] for v in (a.att_start, b.att_start) if v is not None]
    if wa:
        width, n = max(wa), a.pipeline.shape[0]
        for f in ("att_start", "att_finish"):
            drift = max(drift, _nan_drift(
                _pad_att(getattr(a, f), width, n),
                _pad_att(getattr(b, f), width, n)))
    for key in ("ctrl_times", "ctrl_caps", "probe_times", "probe_vals"):
        va, vb = getattr(sr, key), ref[key]
        if (va is None) != (vb is None):
            return float("inf")
        if va is not None:
            drift = max(drift, _nan_drift(va, vb))
    if (sr.fleet_cols is None) != (ref["fleet_cols"] is None):
        return float("inf")
    if sr.fleet_cols is not None:
        for key, va in sr.fleet_cols.items():
            drift = max(drift, _nan_drift(va, ref["fleet_cols"][key]))
    return drift
