"""Streaming trace ingestion & replay (trace-driven operating mode).

The fixed-horizon workload tensor becomes one *source* among several: a
:class:`TraceSource` yields arrival-ordered workload blocks, a
:class:`WorkloadManager` buffers and window-slices them, and
:func:`stream_simulate` runs the stream through the batched JAX engine in
resumable horizon windows — bit-identical to materializing the whole
stream into one call (:func:`oneshot_reference`, gated by
:func:`parity_drift`), with memory bounded by the live backlog instead of
the stream length.
"""
from repro.stream.driver import (StreamResult, oneshot_reference,
                                 parity_drift, stream_simulate)
from repro.stream.sources import (SpanSource, SyntheticSource, TraceSource,
                                  WorkloadManager, materialize)

__all__ = [
    "TraceSource", "SyntheticSource", "SpanSource", "WorkloadManager",
    "materialize", "stream_simulate", "oneshot_reference", "parity_drift",
    "StreamResult",
]
