"""Fig 10 / Fig 12(c) — hour-of-week arrival profile: the clustered
interarrival sampler must reproduce the weekday/weekend and peak-hour
structure of the platform traces."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import empirical_workload, fitted_params, timeit_us
from repro.core.fitting import cluster_of_time
from repro.core.synthesizer import sample_clustered_arrivals
from repro.core.trace import arrivals_per_hour


def rows():
    wl = empirical_workload()
    params = fitted_params()
    out = []

    horizon = 7 * 86400.0
    us, t = timeit_us(
        lambda: np.asarray(sample_clustered_arrivals(
            params.interarrival_clusters, jax.random.PRNGKey(0),
            n_max=int(horizon / 20.0))))
    t = t[t < horizon]
    sim_prof = arrivals_per_hour(t).reshape(-1)
    emp_prof = arrivals_per_hour(np.asarray(wl.arrival)).reshape(-1)
    r = float(np.corrcoef(sim_prof, emp_prof)[0, 1])
    out.append(("fig10_hourofweek_profile_corr", us, f"{r:.4f}"))

    wk = emp_prof.reshape(7, 24)
    sim_wk = sim_prof.reshape(7, 24)
    out.append(("fig10_weekend_damping_emp", us,
                f"{wk[5:].mean() / wk[:5].mean():.3f}"))
    out.append(("fig10_weekend_damping_sim", us,
                f"{sim_wk[5:].mean() / sim_wk[:5].mean():.3f}"))
    out.append(("fig10_peak_hour_emp", us, str(int(wk[:5].mean(0).argmax()))))
    out.append(("fig10_peak_hour_sim", us,
                str(int(sim_wk[:5].mean(0).argmax()))))
    return out


def main():
    for r in rows():
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
