"""Streaming ingestion & replay acceptance bench (``artifacts/BENCH_stream.json``).

Four measurements, one report:

  1. **Windowed parity** (``stream_parity_drift``, gated at exactly 0.0 by
     ``check_drift.py``): a full-stack program — failures/retries +
     closed-loop controller + fleet/trigger lifecycle + probe — streamed
     through :func:`repro.stream.stream_simulate` at SEVERAL window counts
     must be bit-identical (records, per-attempt windows, controller/fleet/
     probe timelines) to materializing the stream into one
     ``simulate_ensemble`` call.
  2. **Replay round-trip** (``replay_roundtrip_drift``, gated too): span
     export -> chunked JSONL (``append=True``) -> :class:`SpanSource` ->
     re-simulate must reproduce every attempt interval bit-exactly on the
     integer-time configuration, windowed replay included.
  3. **Sustained streaming rate**: a :class:`SyntheticSource` stream over
     10x the baseline horizon, consumed with a
     :class:`~repro.ops.accounting.StreamAccumulator` sink — tasks/s and
     the peak working width, which must stay a small fraction of the
     stream length (the bounded-memory claim).
  4. **Ingest overlap**: wall clock with synthesis pipelined under the
     device step vs sequential, same stream
     (``overlap_parity_drift`` gates that the toggle is physics-free).

``REPRO_BENCH_SMOKE=1`` (or ``--smoke``) shrinks horizons for CI.

  PYTHONPATH=src python -m benchmarks.run stream
  PYTHONPATH=src python benchmarks/stream_bench.py --smoke
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                "..")))

import jax

from benchmarks.common import ART, fitted_params
from repro.core import model as M
from repro.core.metrics import FLEET_FIELDS
from repro.core.runtime import FleetSpec, TriggerSpec
from repro.core.synthesizer import synthesize_workload
from repro.obs import ProbeSpec, attempt_intervals_from_records, build_spans
from repro.obs.spans import attempt_intervals, write_spans_jsonl
from repro.ops import FailureModel, ReactiveController, RetryPolicy, Scenario
from repro.ops.accounting import StreamAccumulator
from repro.stream import (SpanSource, SyntheticSource, oneshot_reference,
                          parity_drift, stream_simulate)

OUT_PATH = os.path.abspath(os.path.join(ART, "BENCH_stream.json"))


class _BlockSource:
    """A pinned workload replayed as fixed-size arrival-ordered blocks."""

    name = "bench-blocks"

    def __init__(self, wl, block=64):
        self.wl, self.block = wl, block

    def blocks(self):
        n = self.wl.arrival.shape[0]
        for lo in range(0, n, self.block):
            hi = min(lo + self.block, n)
            yield M.Workload(**{
                f.name: (v[lo:hi] if isinstance(
                    v := getattr(self.wl, f.name), np.ndarray) else v)
                for f in dataclasses.fields(M.Workload)})


def _integer_workload(horizon_s: float, seed: int = 31):
    wl = synthesize_workload(fitted_params(), jax.random.PRNGKey(seed),
                             horizon_s)
    wl.arrival = np.floor(wl.arrival)
    wl.exec_time = np.ceil(wl.exec_time)
    wl.read_bytes[:] = 0.0
    wl.write_bytes[:] = 0.0
    return wl


def _fleet_tensor():
    fl = np.zeros((4, FLEET_FIELDS), np.float32)
    fl[:, 0] = [0.9, 0.8, 0.95, 0.7]
    fl[:, 1] = [2e-3, 1e-3, 5e-4, 3e-3]
    fl[:, 5] = 7 * 24 * 3600.0
    return fl


def _full_stack_kwargs(retry_resample=True):
    return dict(
        scenario=Scenario(
            name="streambench",
            failures=FailureModel(
                p_fail_by_type=(0.25,) * M.N_TASK_TYPES,
                retry=RetryPolicy(max_retries=2, base_s=30.0, mult=2.0,
                                  cap_s=240.0),
                resample_service=retry_resample),
            controller=ReactiveController(
                high_watermark=0.3, low_watermark=0.05, step=0.5,
                min_scale=0.5, max_scale=3.0, interval_s=1800.0)),
        fleet=FleetSpec(params=_fleet_tensor()),
        trigger=TriggerSpec(drift_threshold=0.05, cooldown_s=600.0,
                            obs_noise=0.01, interval_s=300.0,
                            retrain_durations=(400.0, 50.0, 150.0)),
        probe=ProbeSpec(interval_s=900.0))


def rows():
    smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    horizon = (0.1 if smoke else 0.25) * 86400.0
    out_rows = []

    # --- 1. windowed parity: full stack, several window counts -------------
    wl = _integer_workload(horizon)
    src = _BlockSource(wl, block=64)
    kw = _full_stack_kwargs()
    t0 = time.perf_counter()
    ref = oneshot_reference(src, horizon_s=horizon, seed=17, **kw)
    oneshot_wall = time.perf_counter() - t0
    window_counts = (2, 4, 8) if smoke else (2, 4, 8, 16)
    stream_parity_drift = 0.0
    window_walls = {}
    for nw in window_counts:
        sr = stream_simulate(src, horizon_s=horizon, window_s=horizon / nw,
                             seed=17, **kw)
        stream_parity_drift = max(stream_parity_drift,
                                  parity_drift(sr, ref))
        window_walls[nw] = sr.wall_s
    out_rows.append(("stream_parity", oneshot_wall * 1e6,
                     f"drift={stream_parity_drift}_over_"
                     f"{len(window_counts)}window_counts"))

    # --- 2. replay round-trip (integer time, resample off = exactness) ----
    replay_sc = Scenario(name="rp", failures=FailureModel(
        p_fail_by_type=(0.3,) * M.N_TASK_TYPES,
        retry=RetryPolicy(max_retries=2, base_s=30.0, mult=2.0, cap_s=240.0),
        resample_service=False))
    orig = oneshot_reference(src, horizon_s=horizon, seed=17,
                             scenario=replay_sc)
    spans = build_spans(orig["records"], name="streambench")
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "spans.jsonl")
        cut = len(spans) // 3
        write_spans_jsonl(spans[:cut], path)
        write_spans_jsonl(spans[cut:], path, append=True)
        rsrc = SpanSource(path)
    rscn = rsrc.scenario(backoff=replay_sc.failures.retry.backoff)
    rref = oneshot_reference(rsrc, scenario=rscn, horizon_s=horizon)
    got = attempt_intervals_from_records(
        rsrc.remap_pipelines(rref["records"]))
    want = attempt_intervals(spans)
    if set(got) != set(want):
        replay_roundtrip_drift = float("inf")
    else:
        replay_roundtrip_drift = max(
            max(abs(a0 - b0), abs(a1 - b1))
            for (a0, a1), (b0, b1) in ((got[k], want[k]) for k in want))
    rstream = stream_simulate(rsrc, scenario=rscn, horizon_s=horizon,
                              window_s=horizon / 4)
    replay_roundtrip_drift = max(replay_roundtrip_drift,
                                 parity_drift(rstream, rref))
    out_rows.append(("stream_replay_roundtrip", rstream.wall_s * 1e6,
                     f"drift={replay_roundtrip_drift}_"
                     f"{len(want)}intervals_approx{rsrc.n_approximate}"))

    # --- 3. sustained rate over a 10x-horizon stream, sink consumption ----
    mult = 10
    long_h = mult * horizon
    lsrc = SyntheticSource(fitted_params(), seed=23, block_size=256,
                           until_s=long_h)
    acc = StreamAccumulator(M.PlatformConfig().capacities, long_h)
    t0 = time.perf_counter()
    sr_long = stream_simulate(lsrc, horizon_s=long_h, window_s=horizon,
                              seed=23, sink=acc.add)
    long_wall = time.perf_counter() - t0
    tasks_per_s = sr_long.n_task_rows / max(long_wall, 1e-9)
    peak_frac = sr_long.peak_rows / max(sr_long.n_pipelines, 1)
    out_rows.append(("stream_sustained", long_wall * 1e6,
                     f"{tasks_per_s:.0f}tasks/s_{mult}x_horizon_"
                     f"peak{sr_long.peak_rows}of{sr_long.n_pipelines}"))

    # --- 4. ingest overlap on/off: wall only, physics bit-identical -------
    a = stream_simulate(lsrc, horizon_s=long_h, window_s=horizon, seed=23,
                        overlap=True)
    b = stream_simulate(lsrc, horizon_s=long_h, window_s=horizon, seed=23,
                        overlap=False)
    overlap_parity_drift = 0.0
    for f in ("start", "finish", "ready", "attempts"):
        va, vb = getattr(a.records, f), getattr(b.records, f)
        if not np.array_equal(va, vb, equal_nan=True):
            overlap_parity_drift = 1.0
    out_rows.append(("stream_overlap", a.wall_s * 1e6,
                     f"overlap{a.wall_s:.2f}s_sequential{b.wall_s:.2f}s_"
                     f"ingest{a.ingest_s:.2f}s"))

    report = {
        "pipelines": int(wl.n),
        "horizon_s": horizon,
        "window_counts": list(window_counts),
        "stream_parity_drift": stream_parity_drift,
        "oneshot_wall_s": oneshot_wall,
        "window_walls_s": {str(k): v for k, v in window_walls.items()},
        "replay_roundtrip_drift": replay_roundtrip_drift,
        "replay_intervals": len(want),
        "replay_approximate": rsrc.n_approximate,
        "long_horizon_multiple": mult,
        "long_pipelines": int(sr_long.n_pipelines),
        "long_task_rows": int(sr_long.n_task_rows),
        "long_windows": int(sr_long.n_windows),
        "sustained_tasks_per_s": tasks_per_s,
        "sustained_wall_s": long_wall,
        "ingest_s": sr_long.ingest_s,
        "peak_rows": int(sr_long.peak_rows),
        "peak_rows_frac_of_stream": peak_frac,
        "sink_n_tasks": acc.summary()["n_tasks"],
        "overlap_wall_s": a.wall_s,
        "sequential_wall_s": b.wall_s,
        "overlap_parity_drift": overlap_parity_drift,
        "smoke": smoke,
    }
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    return out_rows


def main():
    if "--smoke" in sys.argv[1:]:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    for r in rows():
        print(",".join(str(x) for x in r))
    print(f"# wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
