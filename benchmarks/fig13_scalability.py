"""Fig 13 — simulator performance: wall-clock + memory vs number of pipeline
executions. Paper baseline: ~1.4 ms/pipeline single-thread (720k pipelines
in 8.6 min, <=850 MB, with linear time scaling).

We report the numpy reference engine at several scales, the vectorized JAX
engine, and the vmapped Monte-Carlo ensemble throughput (replicas x
pipelines per wall-second) — the TPU-native win.
"""
from __future__ import annotations

import time
import tracemalloc

import jax
import numpy as np

from benchmarks.common import fitted_params
from repro.core import des, vdes
from repro.core import model as M
from repro.core.synthesizer import synthesize_workload


def rows():
    params = fitted_params()
    out = []
    plat = M.PlatformConfig()

    for days in (0.5, 2.0, 8.0):
        wl = synthesize_workload(params, jax.random.PRNGKey(int(days * 10)),
                                 horizon_s=days * 86400.0)
        tracemalloc.start()
        t0 = time.perf_counter()
        tr = des.simulate(wl, plat)
        wall = time.perf_counter() - t0
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        ms_per_pipeline = wall / wl.n * 1e3
        out.append((f"fig13_numpy_{wl.n}_pipelines_ms_per_pipeline",
                    wall * 1e6, f"{ms_per_pipeline:.4f}"))
        out.append((f"fig13_numpy_{wl.n}_pipelines_peak_mb",
                    wall * 1e6, f"{peak / 2**20:.1f}"))

    # vectorized engine, single replica
    wl = synthesize_workload(params, jax.random.PRNGKey(5),
                             horizon_s=1.0 * 86400.0)
    vwl = vdes.VWorkload.from_workload(wl, plat)
    caps = jax.numpy.asarray(plat.capacities, jax.numpy.int32)
    r = vdes.simulate(vwl, caps)  # compile
    jax.block_until_ready(r["start"])
    t0 = time.perf_counter()
    r = vdes.simulate(vwl, caps)
    jax.block_until_ready(r["start"])
    wall = time.perf_counter() - t0
    out.append((f"fig13_vdes_{wl.n}_pipelines_ms_per_pipeline", wall * 1e6,
                f"{wall / wl.n * 1e3:.4f}"))

    # Monte-Carlo ensemble: R replicas in one vmapped call
    R = 8
    svc = wl.service_time(plat.datastore).astype(np.float32)
    args = [np.tile(np.asarray(a)[None], (R,) + (1,) * np.asarray(a).ndim)
            for a in (wl.arrival.astype(np.float32), wl.n_tasks, wl.task_res,
                      svc, wl.priority)]
    caps_r = np.tile(plat.capacities[None], (R, 1)).astype(np.int32)
    ens = vdes.simulate_ensemble(*[jax.numpy.asarray(a) for a in args],
                                 jax.numpy.asarray(caps_r))
    jax.block_until_ready(ens["start"])
    t0 = time.perf_counter()
    ens = vdes.simulate_ensemble(*[jax.numpy.asarray(a) for a in args],
                                 jax.numpy.asarray(caps_r))
    jax.block_until_ready(ens["start"])
    wall = time.perf_counter() - t0
    out.append((f"fig13_vdes_ensemble_{R}x{wl.n}_pipelines_per_s", wall * 1e6,
                f"{R * wl.n / wall:.0f}"))
    out.append(("fig13_paper_baseline_ms_per_pipeline", 0.0, "1.4"))
    return out


def main():
    for r in rows():
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
