"""Closed-loop control plane & fused admission sort (PR 3 acceptance).

Two measurements, one report (``artifacts/BENCH_controller.json``):

  1. **Closed vs open loop**: a controller-gain grid of the in-engine
     :class:`~repro.ops.capacity.ReactiveController` (ONE batched jit+vmap
     ``Sweep`` call) against the open-loop ``ReactiveAutoscaler`` baseline
     (same watermarks/steps, but each point pays a serial numpy planning
     simulation before it can run). Reports wall clocks, the achieved mean
     waits, and the **realized-vs-planned cost delta** (the summaries charge
     the engine-recorded realized capacity timeline; the delta is what the
     controller's scaling actions were worth in $), plus the
     **numpy-vs-jax drift** of the closed-loop controller on the
     integer-time workload — of the task timestamps AND of the recorded
     realized action timeline (both must be 0.0: the controller does its
     arithmetic in f32 in both engines).
  2. **Fused vs chained admission sort**: the same ensemble executed with
     the single fused ``lax.sort(num_keys=3)`` admission round vs the
     historical 3-chained-argsort wave loop — wave throughput and speedup.
  3. **Waves/s + the batched-vs-serial-numpy crossover** (ROADMAP open
     item 2): wave throughput of both engines on the closed-loop program,
     raw batched walls by width for the uncompacted ensemble AND the
     windowed compaction driver (``repro.core.compaction``;
     ``compaction_speedup_x`` is their ratio at the max width), then
     ENGINE-level interleaved numpy-vs-jax-compact sweep walls, linear
     fits ``wall(B) = a + b*B`` of both, and the grid size at which ONE
     batched compacted call overtakes running the exact numpy engine once
     per point (``batched_vs_numpy_crossover_points``; null if the
     compacted per-point cost never drops below a serial numpy run).

``REPRO_BENCH_SMOKE=1`` (or ``--smoke``) shrinks the horizon/replicas for CI
(`make ci` runs this suite via ``benchmarks.run --smoke``).

  PYTHONPATH=src python -m benchmarks.run controller
  PYTHONPATH=src python benchmarks/controller_bench.py --smoke
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                "..")))

import jax

from benchmarks.common import ART, fitted_params
from repro.core import des, vdes
from repro.core.experiment import ExperimentSpec, Sweep
from repro.core.synthesizer import synthesize_workload
from repro.ops import ReactiveAutoscaler, ReactiveController, Scenario

OUT_PATH = os.path.abspath(os.path.join(ART, "BENCH_controller.json"))

GAINS = [(0.3, 0.5, 4.0), (0.5, 0.25, 2.0), (0.8, 0.25, 2.0),
         (1.0, 0.5, 3.0)]


def _integer_workload(horizon_s: float):
    """Synthesized workload snapped to integer times (arrival floor, exec
    ceil, no IO component) so numpy f64 and JAX f32 agree exactly — the
    drift metric is then a real parity check, not float noise."""
    params = fitted_params()
    wl = synthesize_workload(params, jax.random.PRNGKey(23), horizon_s)
    wl.arrival = np.floor(wl.arrival)
    wl.exec_time = np.ceil(wl.exec_time)
    wl.read_bytes[:] = 0.0
    wl.write_bytes[:] = 0.0
    return wl


def _controller(hw, step, mx, interval):
    return ReactiveController(high_watermark=hw, low_watermark=0.05,
                              step=step, min_scale=0.5, max_scale=mx,
                              interval_s=interval)


def _autoscaler(hw, step, mx, interval):
    return ReactiveAutoscaler(high_watermark=hw, low_watermark=0.05,
                              step=step, min_scale=0.5, max_scale=mx,
                              interval_s=interval)


def rows():
    smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    horizon = (0.125 if smoke else 0.5) * 86400.0
    interval = 1800.0
    wl = _integer_workload(horizon)
    # a deliberately tight platform: congestion is what a controller reacts
    # to (the 48+32-slot default never queues at these horizons)
    base = ExperimentSpec(name="ctrlbench", horizon_s=horizon, engine="jax",
                          workload=wl).with_(
        **{"capacity:compute_cluster": 6, "capacity:learning_cluster": 4})

    # --- closed loop: the whole gain grid is ONE jit+vmap call
    closed_axes = {"controller": [_controller(*g, interval) for g in GAINS]}
    sw = Sweep(base, closed_axes)
    sw.run()                                    # compile
    t0 = time.perf_counter()
    closed = sw.run()
    wall_closed = time.perf_counter() - t0

    # --- open loop: same gains via the planning-pass autoscaler (each grid
    # point must first simulate serially to observe its queues)
    open_axes = {"scenario": [
        Scenario(name=f"auto{i}", capacity=_autoscaler(*g, interval))
        for i, g in enumerate(GAINS)]}
    swo = Sweep(base, open_axes)
    swo.run()                                   # compile (same warm-up as
    t0 = time.perf_counter()                    # the closed-loop side)
    open_ = swo.run()
    wall_open = time.perf_counter() - t0

    wait_closed = float(np.mean([r.summary["mean_wait_s"] for r in closed]))
    wait_open = float(np.mean([r.summary["mean_wait_s"] for r in open_]))
    # realized-vs-planned accounting: the closed-loop summaries charge the
    # engine-recorded capacity timeline, not the pre-planned schedule. A
    # gain setting whose controller never acts omits the planned keys
    # (realized IS planned there): planned falls back to the realized cost
    # and the delta to 0.
    cost_realized = float(np.mean([r.summary["total_cost"] for r in closed]))
    cost_planned = float(np.mean(
        [r.summary.get("planned_total_cost", r.summary["total_cost"])
         for r in closed]))
    cost_delta = float(np.mean(
        [r.summary.get("realized_vs_planned_cost_delta", 0.0)
         for r in closed]))

    # --- numpy-vs-jax closed-loop drift (integer times -> must be 0.0)
    comp = Scenario(name="drift", controller=_controller(
        *GAINS[0], interval)).compile(wl, base.platform, horizon)
    t_np = des.simulate(wl, base.platform, scenario=comp)
    t_jx = vdes.simulate_to_trace(wl, base.platform, scenario=comp)
    live = np.arange(wl.max_tasks)[None, :] < wl.n_tasks[:, None]
    drift = float(np.max(np.abs(
        np.where(live, np.nan_to_num(t_np.start), 0.0)
        - np.where(live, np.nan_to_num(t_jx.start), 0.0))))
    waves_agree = bool(t_np.waves == t_jx.waves)
    # ... and of the recorded realized action timeline itself
    if t_np.ctrl_times.shape == t_jx.ctrl_times.shape:
        timeline_drift = float(max(
            np.max(np.abs(t_np.ctrl_times - t_jx.ctrl_times), initial=0.0),
            np.max(np.abs(t_np.ctrl_caps - t_jx.ctrl_caps), initial=0.0)))
    else:               # different action counts: report the count gap
        timeline_drift = float(abs(t_np.ctrl_times.shape[0]
                                   - t_jx.ctrl_times.shape[0]))

    # --- waves/s + the batched-vs-serial-numpy crossover (ROADMAP open
    # item 2): how many grid points must a sweep have before ONE batched
    # call beats running the exact numpy engine once per point? Three
    # rungs, all on the same closed-loop program:
    #   (a) raw uncompacted ensemble walls by width (transparency: the
    #       pre-compaction baseline, near-flat per-row cost b);
    #   (b) raw compacted-driver walls by width + the CompactionLog
    #       schedule — compaction_speedup_x is (a)/(b) at the max width;
    #   (c) ENGINE-level interleaved numpy-vs-jax-compact sweeps (the
    #       honest ROADMAP framing: the numpy side pays exactly what
    #       `engine="numpy"` pays per point — scenario compile, trace,
    #       summaries — and so does the compacted side). Both sides are
    #       timed min-of-N with the loops interleaved so machine noise
    #       lands on both equally; the crossover comes from linear fits
    #       wall(B) = a + b*B of the ENGINE walls.
    from repro.core import batching, compaction

    t0 = time.perf_counter()
    t_np2 = des.simulate(wl, base.platform, scenario=comp)
    wall_np_point = time.perf_counter() - t0
    numpy_waves_per_s = t_np2.waves / max(wall_np_point, 1e-12)

    widths = [1, 2, 4] if smoke else [1, 2, 4, 8]
    cols_b = batching.pad_workloads([wl] * max(widths), base.platform)
    n_max_b = cols_b.pop("n_max")
    batched_walls = {}
    compacted_walls = {}
    jax_waves_per_s = 0.0
    comp_log = None
    for B in widths:
        scen_kw = batching.stack_scenarios([comp] * B, n_max_b, horizon)
        np_args = [np.asarray(cols_b[k])[:B] for k in
                   ("arrival", "n_tasks", "task_res", "service", "priority")]
        args = [jax.numpy.asarray(a) for a in np_args]
        caps_np = np.tile(
            base.platform.capacities[None], (B, 1)).astype(np.int32)
        caps_b = jax.numpy.asarray(caps_np)
        out_b = vdes.simulate_ensemble(*args, caps_b, **scen_kw)  # compile
        jax.block_until_ready(out_b["start"])
        t0 = time.perf_counter()
        out_b = vdes.simulate_ensemble(*args, caps_b, **scen_kw)
        jax.block_until_ready(out_b["start"])
        batched_walls[B] = time.perf_counter() - t0
        if B == 1:
            jax_waves_per_s = int(out_b["waves"][0]) \
                / max(batched_walls[B], 1e-12)
        ckw = dict(scen_kw)
        ckw["admission_sort"] = "dense"
        comp_log = compaction.CompactionLog()
        compaction.simulate_ensemble_compacted(
            *np_args, caps_np, log=comp_log, **ckw)          # warm shapes
        best = np.inf
        for _ in range(3):
            t0 = time.perf_counter()
            out_c = compaction.simulate_ensemble_compacted(
                *np_args, caps_np, **ckw)
            best = min(best, time.perf_counter() - t0)
        compacted_walls[B] = best
        assert int(np.sum(out_c["waves"])) == int(np.sum(
            np.asarray(out_b["waves"]))), "compacted driver diverged"
    b_max = widths[-1]
    compaction_speedup = batched_walls[b_max] / max(compacted_walls[b_max],
                                                    1e-12)
    compact_waves_per_s = b_max * int(t_np2.waves) \
        / max(compacted_walls[b_max], 1e-12)

    # (c) engine level, interleaved min-of-N (width 16 included even in
    # smoke: the per-point costs are at parity, so the speedup curve is
    # all about amortizing the constant batch dispatch)
    eng_widths = [1, 2, 4, 8, 16]
    ctrl0 = _controller(*GAINS[0], interval)
    sweeps = {}
    for B in eng_widths:
        eng_axes = {"controller": [ctrl0] * B}
        sweeps[("numpy", B)] = Sweep(base.with_(engine="numpy"), eng_axes)
        sweeps[("compact", B)] = Sweep(base.with_(engine="jax-compact"),
                                       eng_axes)
        sweeps[("compact", B)].run()                         # warm shapes
    # best-of-N with the engines interleaved INSIDE each repeat: a load
    # spike or thermal dip lands on both sides of the ratio, and the min
    # over >= 3 repeats pins the speedup/crossover figures to the
    # noise-floor walls instead of whichever single run the scheduler
    # favored (the figure used to swing between CI runs at N=2)
    eng_repeats = 3
    eng_walls = {k: np.inf for k in sweeps}
    for _ in range(eng_repeats):
        for k, sw in sweeps.items():
            t0 = time.perf_counter()
            sw.run()
            eng_walls[k] = min(eng_walls[k], time.perf_counter() - t0)
    bs = np.array(eng_widths, np.float64)
    np_pp, np_disp = np.polyfit(
        bs, [eng_walls[("numpy", B)] for B in eng_widths], 1)
    jc_pp, jc_disp = np.polyfit(
        bs, [eng_walls[("compact", B)] for B in eng_widths], 1)
    speedup_at_max = eng_walls[("numpy", eng_widths[-1])] \
        / max(eng_walls[("compact", eng_widths[-1])], 1e-12)
    # serial numpy beats the batch until B*np_pp exceeds jc_disp + jc_pp*B
    if np_pp > jc_pp:
        crossover = int(np.ceil((jc_disp - np_disp) / (np_pp - jc_pp)))
        crossover = max(crossover, 1)
    else:                   # batched per-point cost >= a serial numpy run
        crossover = None

    # --- fused vs chained admission round (same program, same waves)
    plat = base.platform
    R = 2 if smoke else 4
    svc = wl.service_time(plat.datastore).astype(np.float32)
    cols = [np.tile(np.asarray(a)[None], (R,) + (1,) * np.asarray(a).ndim)
            for a in (wl.arrival.astype(np.float32), wl.n_tasks, wl.task_res,
                      svc, wl.priority)]
    caps = np.tile(plat.capacities[None], (R, 1)).astype(np.int32)

    def timed(sort):
        args = [jax.numpy.asarray(c) for c in cols]
        out = vdes.simulate_ensemble(*args, jax.numpy.asarray(caps),
                                     admission_sort=sort)   # compile
        jax.block_until_ready(out["start"])
        t0 = time.perf_counter()
        out = vdes.simulate_ensemble(*args, jax.numpy.asarray(caps),
                                     admission_sort=sort)
        jax.block_until_ready(out["start"])
        return time.perf_counter() - t0, int(np.sum(np.asarray(out["waves"])))

    wall_fused, waves_f = timed("fused")
    wall_chained, waves_c = timed("chained")
    assert waves_f == waves_c, "sort paths diverged"

    report = {
        "grid_points": len(GAINS),
        "pipelines": wl.n,
        "horizon_s": horizon,
        "closed_loop_wall_s": wall_closed,
        "open_loop_wall_s": wall_open,
        "closed_vs_open_speedup_x": wall_open / max(wall_closed, 1e-12),
        "closed_loop_mean_wait_s": wait_closed,
        "open_loop_mean_wait_s": wait_open,
        "realized_total_cost": cost_realized,
        "planned_total_cost": cost_planned,
        "realized_vs_planned_cost_delta": cost_delta,
        "numpy_vs_jax_drift": drift,
        "realized_timeline_drift": timeline_drift,
        "waves_agree": waves_agree,
        "numpy_wall_per_point_s": wall_np_point,
        "numpy_waves_per_s": numpy_waves_per_s,
        "jax_waves_per_s": jax_waves_per_s,
        "compact_waves_per_s": compact_waves_per_s,
        "batched_wall_by_width_s": {str(k): v
                                    for k, v in batched_walls.items()},
        "compacted_wall_by_width_s": {str(k): v
                                      for k, v in compacted_walls.items()},
        "compaction_speedup_x": compaction_speedup,
        "compaction_segments": comp_log.n_segments,
        "compaction_shapes": [list(s) for s in comp_log.shapes],
        "engine_numpy_wall_by_width_s": {
            str(B): eng_walls[("numpy", B)] for B in eng_widths},
        "engine_compact_wall_by_width_s": {
            str(B): eng_walls[("compact", B)] for B in eng_widths},
        "engine_numpy_per_point_s": float(np_pp),
        "engine_compact_dispatch_s": float(jc_disp),
        "engine_compact_per_point_s": float(jc_pp),
        "batched_vs_numpy_speedup_at_max_width_x": float(speedup_at_max),
        "batched_vs_numpy_crossover_points": crossover,
        "engine_wall_repeats": eng_repeats,
        "fused_wall_s": wall_fused,
        "chained_wall_s": wall_chained,
        "fused_speedup_x": wall_chained / max(wall_fused, 1e-12),
        "waves_total": waves_f,
        "fused_waves_per_s": waves_f / max(wall_fused, 1e-12),
        "chained_waves_per_s": waves_c / max(wall_chained, 1e-12),
        "replicas": R,
        "smoke": smoke,
    }
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)

    return [
        ("controller_closed_loop_grid", wall_closed * 1e6,
         f"{report['closed_vs_open_speedup_x']:.1f}x_vs_open"),
        ("controller_open_loop_grid", wall_open * 1e6,
         f"wait{wait_open:.0f}s_vs_{wait_closed:.0f}s"),
        ("controller_drift", drift * 1e6, f"waves_agree={waves_agree}"),
        ("controller_realized_cost_delta", timeline_drift * 1e6,
         f"realized-planned=${cost_delta:+.2f}"),
        ("admission_sort_fused", wall_fused * 1e6,
         f"{report['fused_waves_per_s']:.0f}waves/s"),
        ("admission_sort_chained", wall_chained * 1e6,
         f"{report['fused_speedup_x']:.2f}x_fused_speedup"),
        ("controller_numpy_waves", wall_np_point * 1e6,
         f"{numpy_waves_per_s:.0f}waves/s"),
        ("controller_compaction", compacted_walls[b_max] * 1e6,
         f"{compaction_speedup:.2f}x_vs_uncompacted_B{b_max}"),
        ("controller_batched_crossover",
         eng_walls[("compact", eng_widths[-1])] * 1e6,
         f"crossover_B={crossover}_speedup{speedup_at_max:.2f}x"),
    ]


def main():
    if "--smoke" in sys.argv[1:]:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    for r in rows():
        print(",".join(str(x) for x in r))
    print(f"# wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
