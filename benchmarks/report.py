"""Generate the §Dry-run and §Roofline tables for EXPERIMENTS.md from the
artifacts. (EXPERIMENTS.md §Perf is written by hand from the hillclimb log.)

  PYTHONPATH=src python -m benchmarks.report > artifacts/report.md
"""
from __future__ import annotations

import json
import os

from repro import configs as CN
from repro.configs.shapes import SHAPES
from repro.core import costmodel as CM


def dryrun_table(mesh: str) -> str:
    lines = [f"### Mesh: {mesh} "
             f"({'2x16x16 = 512 chips' if mesh == 'multi' else '16x16 = 256 chips'})",
             "",
             "| arch | shape | status | flops/dev (raw) | bytes/dev (raw) | "
             "arg GiB | temp GiB | all-reduce | all-gather | reduce-scatter "
             "| all-to-all | permute | compile s |",
             "|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for arch in CN.ARCHS:
        for shape in SHAPES:
            rec = CM.load_cell(mesh, arch, shape)
            if rec is None:
                lines.append(f"| {arch} | {shape} | MISSING | | | | | | | | | | |")
                continue
            if rec["status"] == "skip":
                lines.append(f"| {arch} | {shape} | SKIP (quadratic attn "
                             f"@524k) | | | | | | | | | | |")
                continue
            if rec["status"] != "ok":
                lines.append(f"| {arch} | {shape} | ERROR | | | | | | | | | | |")
                continue
            m = rec["memory"]
            c = rec["collectives"]
            gb = lambda v: f"{v / 2**30:.2f}"
            cb = lambda k: (f"{c[k]['count']}x/"
                            f"{c[k]['bytes'] / 2**20:.0f}MiB"
                            if c[k]["count"] else "—")
            lines.append(
                f"| {arch} | {shape} | ok | {rec['flops_per_device']:.2e} "
                f"| {rec['bytes_accessed_per_device']:.2e} "
                f"| {gb(m.get('argument_size_in_bytes', 0))} "
                f"| {gb(m.get('temp_size_in_bytes', 0))} "
                f"| {cb('all-reduce')} | {cb('all-gather')} "
                f"| {cb('reduce-scatter')} | {cb('all-to-all')} "
                f"| {cb('collective-permute')} | {rec['compile_s']:.0f} |")
    return "\n".join(lines)


def main():
    from benchmarks.roofline import table
    print("## §Dry-run\n")
    print(dryrun_table("single"))
    print()
    print(dryrun_table("multi"))
    print("\n## §Roofline (single pod, scan-corrected audit)\n")
    print(table("single"))


if __name__ == "__main__":
    main()
