"""§Roofline — per (arch x shape) three-term roofline from the dry-run
artifacts + scan-corrected audit (benchmarks/audit.py).

  compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
  memory term     = HLO_bytes / HBM_bw
  collective term = collective_bytes / link_bw

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
Also reports MODEL_FLOPS = 6·N·D (train) / 2·N_active·D (serve), the
useful-compute ratio, and the dominant term per cell.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from repro.configs.shapes import SHAPES
from repro.core import costmodel as CM


def cell_row(mesh: str, arch: str, shape: str) -> Optional[dict]:
    rec = CM.load_cell(mesh, arch, shape)
    if rec is None:
        return None
    if rec.get("status") == "skip":
        return {"arch": arch, "shape": shape, "status": "skip",
                "reason": rec.get("skip_reason", "")}
    if rec.get("status") != "ok":
        return {"arch": arch, "shape": shape, "status": "error"}
    audit = CM.load_audit(mesh, arch, shape)
    if audit is not None and audit.get("status") != "ok":
        audit = None
    terms = CM.roofline_terms(rec, CM.V5E, audit)
    return {"arch": arch, "shape": shape, "status": "ok",
            "audited": audit is not None, **terms}


def rows():
    out = []
    from repro import configs as CN
    for arch in CN.ARCHS:
        for shape in SHAPES:
            r = cell_row("single", arch, shape)
            if r is None:
                continue
            tag = f"roofline_{arch}_{shape}"
            if r["status"] == "skip":
                out.append((tag, 0.0, "SKIP_subquadratic_only"))
                continue
            if r["status"] != "ok":
                out.append((tag, 0.0, "ERROR"))
                continue
            out.append((f"{tag}_dominant", 0.0, r["dominant"]))
            out.append((f"{tag}_step_ms", 0.0, f"{r['step_s'] * 1e3:.3f}"))
            out.append((f"{tag}_roofline_fraction", 0.0,
                        f"{r['roofline_fraction']:.3f}"))
    return out


def table(mesh: str = "single") -> str:
    from repro import configs as CN
    lines = ["| arch | shape | compute_ms | memory_ms | collective_ms | "
             "dominant | MODEL_TF | useful | roofline_frac | audited |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for arch in CN.ARCHS:
        for shape in SHAPES:
            r = cell_row(mesh, arch, shape)
            if r is None:
                lines.append(f"| {arch} | {shape} | - | - | - | MISSING "
                             "| - | - | - | - |")
                continue
            if r["status"] == "skip":
                lines.append(f"| {arch} | {shape} | — | — | — | SKIP "
                             "(quadratic attn @524k) | — | — | — | — |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | - | - | - | ERROR | - "
                             "| - | - | - |")
                continue
            lines.append(
                f"| {arch} | {shape} "
                f"| {r['compute_s'] * 1e3:.3f} | {r['memory_s'] * 1e3:.3f} "
                f"| {r['collective_s'] * 1e3:.3f} | **{r['dominant']}** "
                f"| {r['model_flops'] / 1e12:.1f} "
                f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
                f"| {'y' if r['audited'] else 'raw'} |")
    return "\n".join(lines)


def main():
    for r in rows():
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    import sys
    if "--table" in sys.argv:
        print(table())
    else:
        main()
