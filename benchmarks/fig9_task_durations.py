"""Fig 9 — statistical duration models: preprocess compute-time curve fit
(f(x) = a*b**x + c on ln(rows*cols)) and per-framework training-duration
models. Reports the recovered curve parameters (paper's IBM fit:
a=0.018, b=1.330, c=2.156) and per-framework median durations."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import empirical_workload, fitted_params, timeit_us
from repro.core import model as M
from repro.core import stats


def rows():
    wl = empirical_workload()
    params = fitted_params()
    out = []

    pp = params.preproc
    us, _ = timeit_us(lambda: params.preproc.mean_at(np.linspace(4, 20, 4096)))
    out.append(("fig9a_preproc_curve_a", us, f"{pp.a:.4f}"))
    out.append(("fig9a_preproc_curve_b", us, f"{pp.b:.4f}"))
    out.append(("fig9a_preproc_curve_c", us, f"{pp.c:.4f}"))

    # per-framework medians, empirical vs simulated (Fig 9b: 50% of TF jobs
    # < 180 s vs 50% of SparkML < 10 s in the paper's production data)
    live = np.arange(wl.max_tasks)[None, :] < wl.n_tasks[:, None]
    mtr = (wl.task_type == M.TRAIN) & live
    fw_of_train = np.broadcast_to(wl.framework[:, None], wl.task_type.shape)[mtr]
    dur = wl.exec_time[mtr]
    for f in (M.SPARKML, M.TENSORFLOW):
        emp_med = float(np.median(dur[fw_of_train == f]))
        us, s = timeit_us(
            lambda f=f: np.exp(np.asarray(params.train_loggmm[f].sample(
                jax.random.PRNGKey(0), 4000))[:, 0]))
        sim_med = float(np.median(s))
        name = M.FRAMEWORK_NAMES[f]
        out.append((f"fig9b_{name}_median_emp_s", us, f"{emp_med:.2f}"))
        out.append((f"fig9b_{name}_median_sim_s", us, f"{sim_med:.2f}"))
    return out


def main():
    for r in rows():
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
