"""Reliability-subsystem acceptance bench (``artifacts/BENCH_reliability.json``).

Three measurements, one report:

  1. **Engine parity** (``reliability_parity_drift``, gated at exactly 0.0
     by ``check_drift.py``): a fully-loaded program — correlated domain
     outages through a one-crew repair queue, spot evictions, checkpointed
     retries, plus closed-loop controller and in-loop probe — on an
     integer-grid workload must produce *bit-identical* start/finish
     times, wave counts, fired reliability event records, and probe
     buffers in the numpy reference engine and the JAX engine.
  2. **One-call mixed grid**: a 16-point topology x repair-crews x
     spot x checkpoint sweep must lower to ONE ``simulate_ensemble``
     call (recompile-audited via ``capture_calls``) — padded never-firing
     event rows keep reliability-free points in the same batch.
  3. **Repair-delayed return**: a zone-outage run's realized capacity
     timeline must recover at the repair crew's FIFO finish time, with at
     least one queue-delayed recovery edge — never an instantaneous
     refill. Folded into the drift gate (a violation forces it nonzero).

``REPRO_BENCH_SMOKE=1`` (or ``--smoke``) shrinks the horizon for CI.

  PYTHONPATH=src python -m benchmarks.run reliability
  PYTHONPATH=src python benchmarks/reliability_bench.py --smoke
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                "..")))

import jax

from benchmarks.common import ART, fitted_params
from repro.core import des, vdes
from repro.core.experiment import ExperimentSpec, Sweep
from repro.core.synthesizer import synthesize_workload
from repro.ops import ReactiveController, Scenario
from repro.ops.accounting import realized_schedule
from repro.ops.scenario import compile_static
from repro.reliability import (CheckpointSpec, DomainOutageModel,
                               ReliabilitySpec, RepairSpec, SpotPoolSpec,
                               TopologySpec, compile_reliability)

OUT_PATH = os.path.abspath(os.path.join(ART, "BENCH_reliability.json"))


def _integer_workload(horizon_s: float):
    """Integer-time synthesized workload (arrival floor, exec ceil, no IO):
    with the reliability spec's integer event grid (``time_quantum_s=1``)
    every wave time is exactly representable in f32, so any nonzero drift
    is a real parity break."""
    params = fitted_params()
    wl = synthesize_workload(params, jax.random.PRNGKey(31), horizon_s)
    wl.arrival = np.floor(wl.arrival)
    wl.exec_time = np.ceil(wl.exec_time)
    wl.read_bytes[:] = 0.0
    wl.write_bytes[:] = 0.0
    return wl


def _reliability(horizon_s: float) -> ReliabilitySpec:
    """Dense enough that every channel fires inside the bench horizon:
    zone+rack outages queueing on one crew, spot mass evictions, and
    checkpointed (half-progress) retries."""
    return ReliabilitySpec(
        topology=TopologySpec(zones=2, racks_per_zone=2),
        outages=DomainOutageModel(zone_mtbf_s=horizon_s / 6.0,
                                  rack_mtbf_s=horizon_s / 8.0,
                                  mttr_s=horizon_s / 24.0),
        repair=RepairSpec(crews=1, repair_time_s=horizon_s / 24.0),
        spot=SpotPoolSpec(frac=0.3, evict_mtbe_s=horizon_s / 4.0,
                          reclaim_s=horizon_s / 48.0, discount=0.35),
        checkpoint=CheckpointSpec(ckpt_frac=0.5),
        time_quantum_s=1.0)


def rows():
    smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    horizon = (0.125 if smoke else 0.5) * 86400.0
    wl = _integer_workload(horizon)
    base = ExperimentSpec(name="relbench", horizon_s=horizon,
                          workload=wl).with_(
        **{"capacity:compute_cluster": 6, "capacity:learning_cluster": 4})
    plat = base.platform
    rel_spec = _reliability(horizon)
    rel = compile_reliability(rel_spec, wl, plat, horizon, seed=17)

    ctrl_sc = Scenario(name="ctrl", controller=ReactiveController(
        high_watermark=0.3, low_watermark=0.05, step=0.5, min_scale=0.5,
        max_scale=3.0, interval_s=1800.0))
    from repro.obs import ProbeSpec, compile_probe
    comp = ctrl_sc.compile(wl, plat, horizon, seed=17)
    probe = compile_probe(ProbeSpec(interval_s=900.0), horizon)

    # --- 1. bit parity: the fully-loaded program, both engines
    t0 = time.perf_counter()
    t_np = des.simulate(wl, plat, scenario=comp, probe=probe,
                        reliability=rel)
    wall_np = time.perf_counter() - t0
    t_jx = vdes.simulate_to_trace(wl, plat, scenario=comp, probe=probe,
                                  reliability=rel)
    waves_agree = bool(t_np.waves == t_jx.waves)
    drift = 0.0
    for k in ("start", "finish", "ready"):
        if not np.array_equal(getattr(t_np, k), getattr(t_jx, k),
                              equal_nan=True):
            drift = 1.0
    if not (np.array_equal(t_np.rel_times, t_jx.rel_times)
            and np.array_equal(t_np.rel_caps, t_jx.rel_caps)):
        drift = 1.0
    probe_drift = float(np.max(np.abs(
        np.nan_to_num(t_np.probe_vals) - np.nan_to_num(t_jx.probe_vals))))
    if not waves_agree:
        drift = 1.0
    drift = max(drift, probe_drift)

    # --- 2. one-call mixed grid (recompile audit)
    from repro.analysis.harness import capture_calls
    sweep = Sweep(dataclasses.replace(base, engine="jax",
                                      reliability=rel_spec), {
        "reliability:topology": [TopologySpec(2, 2), TopologySpec(3, 2)],
        "reliability:repair": [RepairSpec(crews=1, repair_time_s=horizon
                                          / 24.0),
                               RepairSpec(crews=4, repair_time_s=horizon
                                          / 24.0)],
        "reliability:spot": [None, SpotPoolSpec(frac=0.3)],
        "reliability:checkpoint": [None, CheckpointSpec(ckpt_frac=0.5)],
    })
    t0 = time.perf_counter()
    with capture_calls("simulate_ensemble") as calls:
        results = sweep.run()
    sweep_wall = time.perf_counter() - t0
    one_call = len(calls) == 1 and calls[0].args[0].shape[0] == 16
    if not one_call:
        drift = max(drift, 1.0)
    avail = [r.summary["availability"]["availability"]["compute_cluster"]
             for r in results if "availability" in r.summary]

    # --- 3. repair-delayed capacity return on the realized timeline
    sched = realized_schedule(t_np, compile_static(wl, plat))
    dips = bool((sched.caps < plat.capacities[None, :]).any())
    rises = np.nonzero((np.diff(sched.caps, axis=0) > 0).any(1))[0] + 1
    up_times = {float(np.float32(e.t_up)) for e in rel.events
                if e.t_up < horizon}
    edges_are_up_events = all(float(t) in up_times
                              for t in sched.times[rises])
    delayed = {float(np.float32(e.t_up)) for e in rel.events
               if e.repair_wait > 0 and e.t_up < horizon}
    queue_delayed = bool(delayed & set(map(float, sched.times[rises])))
    delayed_return_ok = dips and edges_are_up_events and queue_delayed
    if not delayed_return_ok:
        drift = max(drift, 1.0)

    report = {
        "pipelines": wl.n,
        "horizon_s": horizon,
        "n_rel_events": rel.n_events,
        "reliability_parity_drift": drift,
        "waves_agree": waves_agree,
        "sweep_points": len(results),
        "sweep_one_call": one_call,
        "sweep_wall_s": sweep_wall,
        "availability_min": min(avail) if avail else None,
        "availability_max": max(avail) if avail else None,
        "repair_queue_depth_max": rel.repair_depth_max,
        "repair_wait_mean_s": float(rel.repair_waits.mean())
        if rel.repair_waits.size else 0.0,
        "n_straggler_repairs": rel.n_straggler_repairs,
        "evicted_tasks": int(rel.evict_attempts.sum())
        if rel.evict_attempts is not None else 0,
        "delayed_return_ok": delayed_return_ok,
        "numpy_wall_s": wall_np,
        "smoke": smoke,
    }
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)

    return [
        ("reliability_parity", wall_np * 1e6,
         f"drift={drift}_events={rel.n_events}_waves_agree={waves_agree}"),
        ("reliability_sweep", sweep_wall * 1e6,
         f"{len(results)}pts_one_call={one_call}"),
        ("reliability_delayed_return", float(rel.repair_waits.max()
                                             if rel.repair_waits.size
                                             else 0.0) * 1e6,
         f"ok={delayed_return_ok}_depth={rel.repair_depth_max}"),
    ]


def main():
    if "--smoke" in sys.argv[1:]:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    for r in rows():
        print(",".join(str(x) for x in r))
    print(f"# wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
