"""Benchmark harness — one module per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV rows (one per measured quantity).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig13      # one suite
  PYTHONPATH=src python -m benchmarks.run --smoke    # fast CI subset
"""
from __future__ import annotations

import os
import sys
import traceback

SUITES = [
    ("table1", "benchmarks.table1_compression"),
    ("fig9", "benchmarks.fig9_task_durations"),
    ("fig10", "benchmarks.fig10_arrivals"),
    ("fig11", "benchmarks.fig11_saturation"),
    ("fig12", "benchmarks.fig12_accuracy"),
    ("fig13", "benchmarks.fig13_scalability"),
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline", "benchmarks.roofline"),
    ("scenarios", "benchmarks.scenario_bench"),
    ("sweep", "benchmarks.sweep_bench"),
    ("controller", "benchmarks.controller_bench"),
    ("feedback", "benchmarks.feedback_bench"),
    ("obs", "benchmarks.obs_bench"),
    ("stream", "benchmarks.stream_bench"),
    ("reliability", "benchmarks.reliability_bench"),
]

# fast subset for CI: shrunken sizes via REPRO_BENCH_SMOKE ("kernels"
# rides along for artifacts/BENCH_kernels.json — in smoke mode it skips
# the heavy reference-kernel rows and runs only the admission/compaction
# parity section)
SMOKE_SUITES = ("scenarios", "sweep", "controller", "feedback", "obs",
                "kernels", "stream", "reliability")


def main() -> None:
    import importlib

    args = sys.argv[1:]
    smoke = "--smoke" in args
    unknown = [a for a in args if a.startswith("-") and a != "--smoke"]
    if unknown:
        print(f"unknown option(s): {' '.join(unknown)}", file=sys.stderr)
        sys.exit(2)
    names = [a for a in args if not a.startswith("-")]
    which = names[0] if names else None
    suites = SUITES
    if smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        if which is None:        # bare --smoke: the fast CI subset
            suites = [(t, m) for t, m in SUITES if t in SMOKE_SUITES]
    print("name,us_per_call,derived")
    for tag, modname in suites:
        if which and which != tag:
            continue
        try:
            mod = importlib.import_module(modname)
            for row in mod.rows():
                print(",".join(str(x) for x in row), flush=True)
        except Exception as e:
            traceback.print_exc()
            print(f"{tag}_FAILED,0,{type(e).__name__}", flush=True)


if __name__ == "__main__":
    main()
