"""Benchmark harness — one module per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV rows (one per measured quantity).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig13      # one suite
"""
from __future__ import annotations

import sys
import traceback

SUITES = [
    ("table1", "benchmarks.table1_compression"),
    ("fig9", "benchmarks.fig9_task_durations"),
    ("fig10", "benchmarks.fig10_arrivals"),
    ("fig11", "benchmarks.fig11_saturation"),
    ("fig12", "benchmarks.fig12_accuracy"),
    ("fig13", "benchmarks.fig13_scalability"),
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline", "benchmarks.roofline"),
]


def main() -> None:
    import importlib

    which = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for tag, modname in SUITES:
        if which and which != tag:
            continue
        try:
            mod = importlib.import_module(modname)
            for row in mod.rows():
                print(",".join(str(x) for x in row), flush=True)
        except Exception as e:
            traceback.print_exc()
            print(f"{tag}_FAILED,0,{type(e).__name__}", flush=True)


if __name__ == "__main__":
    main()
