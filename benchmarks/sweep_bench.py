"""Sweep-grid throughput: one batched jit+vmap call vs the serial loop.

A ~24-point experiment grid (learning-cluster capacities x interarrival
factors x operational-scenario families) executed three ways:

  - ``Sweep(...).run`` on the JAX engine — the whole grid lowers through
    ``repro.core.batching`` into ONE ``vdes.simulate_ensemble`` call;
  - the legacy serial loop on the JAX engine (per-point
    ``run_experiment``, recompiling per workload shape);
  - the legacy serial loop on the numpy engine (the old default path).

Emits ``artifacts/BENCH_sweep.json`` so sweep throughput is tracked across
PRs. ``REPRO_BENCH_SMOKE=1`` (or ``--smoke``) shrinks the horizon for CI but
keeps the 24-point grid shape.

  PYTHONPATH=src python -m benchmarks.run sweep
  PYTHONPATH=src python benchmarks/sweep_bench.py --smoke
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                "..")))

from benchmarks.common import ART, fitted_params
from repro.core import model as M
from repro.core.experiment import ExperimentSpec, Sweep, run_experiment
from repro.ops import (FailureModel, MaintenanceWindows, Scenario,
                       ScheduledAutoscaler, SLOConfig)

OUT_PATH = os.path.abspath(os.path.join(ART, "BENCH_sweep.json"))


def build_sweep(horizon_s: float) -> Sweep:
    """~24 points: scheduler x load x scenario family. Every serial point
    recompiles (policy is a static jit argument; each interarrival factor
    changes the workload shape; each scenario family changes the schedule
    shape) while the batched path compiles ONE program: policies ride the
    traced ``policies [B]`` tensor, schedules/attempts the stacked scenario
    tensors."""
    from repro.core import des
    slo = SLOConfig()
    scenarios = [
        None,
        Scenario(name="failures", failures=FailureModel(), slo=slo),
        Scenario(name="maintenance", slo=slo,
                 capacity=MaintenanceWindows(
                     windows=((0.1 * horizon_s, 0.4 * horizon_s, 1, 0.5),))),
        Scenario(name="predictive", slo=slo,
                 capacity=ScheduledAutoscaler(min_scale=0.6, max_scale=1.25)),
    ]
    base = ExperimentSpec(name="sweepbench", horizon_s=horizon_s,
                          engine="jax", seed=17)
    return Sweep(base, {
        "policy": [des.POLICY_FIFO, des.POLICY_SJF, des.POLICY_PRIORITY],
        "interarrival_factor": [0.9, 1.2],
        "scenario": scenarios,
    })


def rows():
    smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    horizon = (0.125 if smoke else 0.25) * 86400.0
    params = fitted_params()
    sw = build_sweep(horizon)
    points = sw.points()
    G = len(points)

    # pre-warm the synthesizer jit caches (shared in-process by every path,
    # so whichever path ran first would otherwise eat the one-time compile)
    import jax
    from repro.core.synthesizer import synthesize_workload
    for ia in sorted({p.interarrival_factor for p in points}):
        synthesize_workload(params, jax.random.PRNGKey(17), horizon,
                            points[0].platform, ia)

    t0 = time.perf_counter()
    batched = sw.run(params)
    wall_batched = time.perf_counter() - t0

    t0 = time.perf_counter()
    serial_jax = [run_experiment(p, params) for p in points]
    wall_serial_jax = time.perf_counter() - t0

    t0 = time.perf_counter()
    serial_np = [run_experiment(p.with_(engine="numpy"), params)
                 for p in points]
    wall_serial_np = time.perf_counter() - t0

    # sanity: the batched grid reproduces the serial per-point physics
    drift = max(abs(b.summary["mean_wait_s"] - s.summary["mean_wait_s"])
                / max(s.summary["mean_wait_s"], 1.0)
                for b, s in zip(batched, serial_jax))
    n_total = sum(r.records.start.shape[0] for r in batched)

    report = {
        "grid_points": G,
        "axes": {"policy": 3, "interarrival_factor": 2,
                 "scenario_families": 4},
        "tasks_total": int(n_total),
        "batched_wall_s": wall_batched,
        "serial_jax_wall_s": wall_serial_jax,
        "serial_numpy_wall_s": wall_serial_np,
        "speedup_x": wall_serial_jax / max(wall_batched, 1e-12),
        "speedup_vs_numpy_x": wall_serial_np / max(wall_batched, 1e-12),
        "max_rel_drift_vs_serial": drift,
        "horizon_s": horizon,
        "smoke": smoke,
    }
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)

    return [
        (f"sweep_batched_{G}pt", wall_batched * 1e6,
         f"{G / max(wall_batched, 1e-12):.2f}pts/s"),
        (f"sweep_serial_jax_{G}pt", wall_serial_jax * 1e6,
         f"{report['speedup_x']:.1f}x"),
        (f"sweep_serial_numpy_{G}pt", wall_serial_np * 1e6,
         f"{report['speedup_vs_numpy_x']:.1f}x"),
    ]


def main():
    if "--smoke" in sys.argv[1:]:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    for r in rows():
        print(",".join(str(x) for x in r))
    print(f"# wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
