"""Table I — model-compression effects on accuracy / size / inference time.

Validates that the compression-effect model reproduces the paper's measured
pruning table exactly at the knots (interp mode) and reports the quadratic
regression residual (the paper: "the relative changes … could be described
by a regression model")."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timeit_us
from repro.core.metrics import (PRUNE_LEVELS, TABLE1, apply_compression,
                                compression_effect)


def rows():
    out = []
    for arch in ("googlenet", "resnet50"):
        for metric in ("accuracy", "size_mb", "inference_ms"):
            tab = TABLE1[arch][metric]
            us, got = timeit_us(
                lambda a=arch, m=metric: compression_effect(
                    PRUNE_LEVELS, a, m, mode="interp") * TABLE1[a][m][0])
            knot_err = float(np.max(np.abs(got - tab)))
            us2, got2 = timeit_us(
                lambda a=arch, m=metric: compression_effect(
                    PRUNE_LEVELS, a, m, mode="poly") * TABLE1[a][m][0])
            poly_rmse = float(np.sqrt(np.mean((got2 - tab) ** 2)))
            out.append((f"table1_{arch}_{metric}_knot_maxerr", us,
                        f"{knot_err:.4g}"))
            out.append((f"table1_{arch}_{metric}_poly_rmse", us2,
                        f"{poly_rmse:.3f}"))

    # end-to-end asset mutation at 40% pruning (resnet50 row)
    rng = np.random.default_rng(0)
    perf = np.full(1000, 0.813)
    size = np.full(1000, 91.1e6)
    us, (p2, s2) = timeit_us(
        lambda: apply_compression(perf, size, np.full(1000, 0.4),
                                  "resnet50", rng))
    out.append(("table1_apply_40pct_acc_rel", us,
                f"{float(p2.mean() / 0.813):.4f}"))
    out.append(("table1_apply_40pct_size_rel", us,
                f"{float(s2.mean() / 91.1e6):.4f}"))
    return out


def main():
    for r in rows():
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
