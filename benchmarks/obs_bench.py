"""Telemetry-plane acceptance bench (``artifacts/BENCH_obs.json``).

Four measurements, one report:

  1. **Probe parity** (``probe_parity_drift``, gated at exactly 0.0 by
     ``check_drift.py``): a fully-loaded program — closed-loop controller +
     model-lifecycle fleet + in-loop probe — on an integer-time workload
     must fill *bit-identical* probe buffers in the numpy reference engine
     and the vmapped JAX engine, wave counts included.
  2. **Span export round-trip** (``span_roundtrip_drift``, gated too): the
     probed run's Chrome-trace export must reconstruct every attempt
     interval bit-exactly against ``TaskRecords`` (the acceptance
     criterion), and the JSONL export must parse back equal.
  3. **Self-profile**: compile-vs-execute wall split of the JAX engine and
     waves/s for BOTH engines on the same program.
  4. **Per-stage attribution**: differential-ablation cost of each optional
     kernel stage (control / fleet / probe) over the
     select+completion+admission core, per wave.

``REPRO_BENCH_SMOKE=1`` (or ``--smoke``) shrinks the horizon for CI.

  PYTHONPATH=src python -m benchmarks.run obs
  PYTHONPATH=src python benchmarks/obs_bench.py --smoke
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                "..")))

import jax

from benchmarks.common import ART, fitted_params
from repro.core import des, trace, vdes
from repro.core.metrics import FLEET_FIELDS
from repro.core.runtime import FleetSpec, TriggerSpec
from repro.core.synthesizer import synthesize_workload
from repro.obs import (ProbeSpec, attempt_intervals_from_records,
                       build_spans, compile_probe, profile_compile_execute,
                       profile_numpy, read_chrome_attempt_intervals,
                       read_spans_jsonl, stage_attribution,
                       write_chrome_trace, write_spans_jsonl)
from repro.ops import ReactiveController, Scenario
from repro.ops.scenario import compile_fleet

OUT_PATH = os.path.abspath(os.path.join(ART, "BENCH_obs.json"))


def _integer_workload(horizon_s: float):
    """Integer-time synthesized workload (arrival floor, exec ceil, no IO)
    so the f32 probe arithmetic has no representation error to hide behind:
    any nonzero drift is a real parity break."""
    params = fitted_params()
    wl = synthesize_workload(params, jax.random.PRNGKey(29), horizon_s)
    wl.arrival = np.floor(wl.arrival)
    wl.exec_time = np.ceil(wl.exec_time)
    wl.read_bytes[:] = 0.0
    wl.write_bytes[:] = 0.0
    return wl


def _fleet_tensor():
    fl = np.zeros((4, FLEET_FIELDS), np.float32)
    fl[:, 0] = [0.9, 0.8, 0.95, 0.7]
    fl[:, 1] = [2e-3, 1e-3, 5e-4, 3e-3]
    fl[:, 5] = 7 * 24 * 3600.0
    return fl


def rows():
    smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    horizon = (0.125 if smoke else 0.5) * 86400.0
    wl = _integer_workload(horizon)
    from repro.core.experiment import ExperimentSpec
    base = ExperimentSpec(name="obsbench", horizon_s=horizon,
                          workload=wl).with_(
        **{"capacity:compute_cluster": 6, "capacity:learning_cluster": 4})
    plat = base.platform

    trig = TriggerSpec(drift_threshold=0.05, cooldown_s=600.0,
                       obs_noise=0.01, interval_s=300.0,
                       retrain_durations=(400.0, 50.0, 150.0))
    ctrl_sc = Scenario(name="ctrl", controller=ReactiveController(
        high_watermark=0.3, low_watermark=0.05, step=0.5, min_scale=0.5,
        max_scale=3.0, interval_s=1800.0))
    cf, ext = compile_fleet(FleetSpec(params=_fleet_tensor()), trig, wl,
                            plat, horizon, seed=11)
    comp = ctrl_sc.compile(ext, plat, horizon, seed=11)
    probe = compile_probe(ProbeSpec(interval_s=900.0), horizon,
                          n_models=cf.n_models)

    # --- 1. probe parity: the fully-loaded program, both engines
    t0 = time.perf_counter()
    t_np = des.simulate(ext, plat, scenario=comp, fleet=cf, probe=probe)
    wall_np = time.perf_counter() - t0
    t_jx = vdes.simulate_to_trace(ext, plat, scenario=comp, fleet=cf,
                                  probe=probe)
    waves_agree = bool(t_np.waves == t_jx.waves)
    probe_parity_drift = float(np.max(np.abs(
        np.nan_to_num(t_np.probe_vals) - np.nan_to_num(t_jx.probe_vals))))
    nan_masks_agree = bool(np.array_equal(np.isnan(t_np.probe_vals),
                                          np.isnan(t_jx.probe_vals)))
    if not (waves_agree and nan_masks_agree):
        probe_parity_drift = max(probe_parity_drift, 1.0)

    # --- 2. span export round-trip (the acceptance criterion)
    rec = trace.flatten_trace(t_np, ext)
    spans = build_spans(rec, t_np, name="obsbench")
    with tempfile.TemporaryDirectory() as tmp:
        jsonl = os.path.join(tmp, "spans.jsonl")
        chrome = os.path.join(tmp, "trace.json")
        write_spans_jsonl(spans, jsonl)
        write_chrome_trace(spans, chrome)
        jsonl_ok = read_spans_jsonl(jsonl) == spans
        want = attempt_intervals_from_records(rec)
        got = read_chrome_attempt_intervals(chrome)
    span_roundtrip_drift = 0.0 if (jsonl_ok and got == want) else 1.0
    n_spans = len(spans)

    # --- 3. self-profile: compile/execute split + waves/s, both engines
    prof_np = profile_numpy(ext, plat, scenario=comp, fleet=cf, probe=probe,
                            repeats=1 if smoke else 3)
    prof_jx = profile_compile_execute(ext, plat, scenario=comp, fleet=cf,
                                      probe=probe,
                                      repeats=1 if smoke else 3)

    # --- 4. per-stage attribution by differential ablation
    stages = stage_attribution(ext, plat, scenario=comp, fleet=cf,
                               probe=probe, repeats=1 if smoke else 3)

    report = {
        "pipelines": wl.n,
        "horizon_s": horizon,
        "probe_ticks": probe.n_ticks,
        "probe_parity_drift": probe_parity_drift,
        "waves_agree": waves_agree,
        "span_roundtrip_drift": span_roundtrip_drift,
        "n_spans": n_spans,
        "n_attempt_intervals": len(want),
        "numpy_wall_s": prof_np["wall_s"],
        "numpy_waves_per_s": prof_np["waves_per_s"],
        "jax_compile_s": prof_jx["compile_s"],
        "jax_execute_s": prof_jx["execute_s"],
        "jax_waves_per_s": prof_jx["waves_per_s"],
        "waves": prof_jx["waves"],
        "stage_attribution_us_per_wave": {
            k: v["per_wave_us"] for k, v in stages.items()},
        "stage_walls_s": {k: v["wall_s"] for k, v in stages.items()},
        "smoke": smoke,
    }
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)

    return [
        ("obs_probe_parity", wall_np * 1e6,
         f"drift={probe_parity_drift}_waves_agree={waves_agree}"),
        ("obs_span_roundtrip", span_roundtrip_drift * 1e6,
         f"{len(want)}intervals_{n_spans}spans"),
        ("obs_numpy_engine", prof_np["wall_s"] * 1e6,
         f"{prof_np['waves_per_s']:.0f}waves/s"),
        ("obs_jax_engine", prof_jx["execute_s"] * 1e6,
         f"{prof_jx['waves_per_s']:.0f}waves/s_compile"
         f"{prof_jx['compile_s']:.1f}s"),
        ("obs_stage_probe", stages.get("probe", {}).get("per_wave_us", 0.0),
         "us_per_wave_delta"),
    ]


def main():
    if "--smoke" in sys.argv[1:]:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    for r in rows():
        print(",".join(str(x) for x in r))
    print(f"# wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
