"""Fig 12 — simulation accuracy: Q-Q agreement (log10 seconds) between
empirical and simulated task-duration / interarrival distributions.

The paper reports visual Q-Q agreement; we quantify it as the R^2 of the Q-Q
scatter against y=x plus max |deviation| in log10 space.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import empirical_workload, fitted_params, timeit_us
from repro.core import model as M
from repro.core import stats
from repro.core.synthesizer import sample_clustered_arrivals, synthesize_workload


def _durations(wl, ttype):
    live = np.arange(wl.max_tasks)[None, :] < wl.n_tasks[:, None]
    m = (wl.task_type == ttype) & live
    return wl.exec_time[m]


def rows():
    wl = empirical_workload()
    params = fitted_params()
    us_syn, syn = timeit_us(lambda: synthesize_workload(
        params, jax.random.PRNGKey(11), horizon_s=2 * 86400.0), repeat=1)
    out = []

    for ttype, nm in ((M.PREPROCESS, "preprocess"), (M.TRAIN, "train"),
                      (M.EVALUATE, "evaluate")):
        qq = stats.qq_stats(_durations(wl, ttype), _durations(syn, ttype))
        out.append((f"fig12a_{nm}_qq_r2", us_syn, f"{qq['r2']:.4f}"))
        out.append((f"fig12a_{nm}_qq_maxdev_log10", us_syn,
                    f"{qq['max_abs_dev_log10']:.3f}"))

    # interarrivals: random (global fit) and realistic (clustered) profiles
    emp_ia = np.diff(np.sort(np.asarray(wl.arrival)))
    us_g, s_g = timeit_us(lambda: np.asarray(
        params.interarrival_global.sample(jax.random.PRNGKey(1), (40000,))))
    qq = stats.qq_stats(emp_ia, s_g)
    out.append(("fig12b_interarrival_random_qq_r2", us_g, f"{qq['r2']:.4f}"))

    us_c, t = timeit_us(lambda: np.asarray(sample_clustered_arrivals(
        params.interarrival_clusters, jax.random.PRNGKey(2), n_max=40000)))
    qq = stats.qq_stats(emp_ia, np.diff(t))
    out.append(("fig12b_interarrival_clustered_qq_r2", us_c,
                f"{qq['r2']:.4f}"))
    return out


def main():
    for r in rows():
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
