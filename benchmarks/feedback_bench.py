"""Model-lifecycle feedback loop: in-engine batched grid vs the serial
reference loop (PR 5 acceptance).

Two measurements, one report (``artifacts/BENCH_feedback.json``):

  1. **One-call trigger grid vs serial loop**: a >= 12-point lifecycle-policy
     grid (``trigger:drift_threshold`` x ``trigger:cooldown_s`` x
     ``fleet:drift_scale``) through ``Sweep`` on the JAX engine — the whole
     grid is ONE ``jit``+``vmap`` ``simulate_ensemble`` call — against the
     serial reference (one exact numpy-engine run per point, the successor
     of the old windowed ``run_feedback_simulation`` co-simulation). Also
     reports the **cost-vs-staleness frontier** the grid traces out
     (provisioned cost vs mean staleness / retrain count per point).
  2. **feedback_parity_drift**: numpy-vs-jax wave-for-wave parity with the
     feedback stage enabled on an integer-time workload — the max absolute
     difference over task timestamps, trigger times, redeploy times, AND
     the per-tick performance/staleness timelines. Must be exactly 0.0
     (the fleet stage accumulates presampled f32 drift increments in both
     engines); ``benchmarks/check_drift.py`` gates it in ``make ci``.

``REPRO_BENCH_SMOKE=1`` (or ``--smoke``) shrinks the horizon/grid for CI.

  PYTHONPATH=src python -m benchmarks.run feedback
  PYTHONPATH=src python benchmarks/feedback_bench.py --smoke
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                "..")))

import jax

from benchmarks.common import ART, fitted_params
from repro.core import des, vdes
from repro.core.experiment import ExperimentSpec, Sweep, run_experiment
from repro.core.metrics import FLEET_FIELDS
from repro.core.runtime import FleetSpec, TriggerSpec
from repro.core.synthesizer import synthesize_workload
from repro.ops import Scenario
from repro.ops.scenario import compile_fleet

OUT_PATH = os.path.abspath(os.path.join(ART, "BENCH_feedback.json"))


def _integer_workload(horizon_s: float):
    """Synthesized workload snapped to integer times (arrival floor, exec
    ceil, no IO) so numpy f64 and JAX f32 agree exactly — the drift metric
    is then a real parity check, not float noise."""
    params = fitted_params()
    wl = synthesize_workload(params, jax.random.PRNGKey(31), horizon_s)
    wl.arrival = np.floor(wl.arrival)
    wl.exec_time = np.ceil(wl.exec_time)
    wl.read_bytes[:] = 0.0
    wl.write_bytes[:] = 0.0
    return wl


def _fleet_tensor(n_models: int):
    """Deterministic drift processes, seasonal OFF (the bit-parity
    configuration) — accelerated-aging rates so a sub-day horizon sees the
    whole trigger->retrain->redeploy cycle several times."""
    r = np.random.default_rng(5)
    fl = np.zeros((n_models, FLEET_FIELDS), np.float32)
    fl[:, 0] = np.clip(r.beta(10, 3, n_models), 0.5, 0.995)
    fl[:, 1] = r.lognormal(np.log(2e-5), 0.6, n_models)   # gradual /s
    fl[:, 2] = r.lognormal(np.log(1 / (4 * 3600.0)), 0.5, n_models)
    fl[:, 3] = r.uniform(0.01, 0.05, n_models)
    fl[:, 5] = 7 * 24 * 3600.0
    return fl


def rows():
    smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    horizon = (0.125 if smoke else 0.5) * 86400.0
    n_models = 6 if smoke else 12
    interval = 900.0
    params = fitted_params()
    trig = TriggerSpec(drift_threshold=0.04, cooldown_s=3600.0,
                       obs_noise=0.005, interval_s=interval,
                       retrain_durations=(1200.0, 90.0, 30.0))
    base = ExperimentSpec(name="fb", horizon_s=horizon, engine="jax",
                          seed=31, scenario=Scenario(name="static"),
                          fleet=FleetSpec(params=_fleet_tensor(n_models)),
                          trigger=trig).with_(
        **{"capacity:compute_cluster": 8, "capacity:learning_cluster": 6})

    axes = {"trigger:drift_threshold": [0.02, 0.04, 0.08],
            "trigger:cooldown_s": [1800.0, 7200.0],
            "fleet:drift_scale": [1.0, 2.0]}      # 3 x 2 x 2 = 12 points
    sw = Sweep(base, axes)
    points = sw.points()

    # --- batched: the whole lifecycle-policy grid in ONE jit+vmap call
    # (workload synthesis deduped across the grid, one XLA compile)
    sw.run(params)                              # compile
    t0 = time.perf_counter()
    batched = sw.run(params)
    wall_batched = time.perf_counter() - t0

    # --- serial reference loop (the old windowed co-simulation's working
    # style: one exact numpy-engine run per grid point, each paying its
    # own synthesis — what a lifecycle-policy study cost before PR 5)
    t0 = time.perf_counter()
    serial = [run_experiment(p.with_(engine="numpy"), params)
              for p in points]
    wall_serial = time.perf_counter() - t0

    # --- cost-vs-staleness frontier + batched-vs-serial summary gap
    # (synthesized f64-vs-f32 workloads: a small gap is float noise, NOT
    # engine drift — the gated 0.0 parity check runs below on an
    # integer-time workload)
    frontier = []
    summary_gap = 0.0
    for p, b, s in zip(points, batched, serial):
        frontier.append({
            "point": p.name.split("/", 1)[-1],
            "total_cost": b.summary["total_cost"],
            "retrain_node_hours":
                b.summary["lifecycle"]["retrain_node_seconds"] / 3600.0,
            "mean_staleness": b.summary["mean_staleness"],
            "staleness_integral_s": b.summary["staleness_integral_s"],
            "n_retrained": b.summary["n_retrained"],
        })
        summary_gap = max(
            summary_gap,
            abs(b.summary["mean_staleness"] - s.summary["mean_staleness"]))

    # --- engine-level parity: one config, numpy vs jax, wave-for-wave on
    # an integer-time workload (exactly representable in f32)
    wl = _integer_workload(horizon)
    cf, ext = compile_fleet(base.fleet, trig, wl, base.platform, horizon,
                            seed=0)
    t_np = des.simulate(ext, base.platform, fleet=cf)
    t_jx = vdes.simulate_to_trace(ext, base.platform, fleet=cf)
    live = np.arange(ext.max_tasks)[None, :] < ext.n_tasks[:, None]
    live = live & np.isfinite(t_np.arrival)[:, None]
    drift = max(
        float(np.max(np.abs(np.where(live, np.nan_to_num(t_np.start), 0.0)
                            - np.where(live, np.nan_to_num(t_jx.start),
                                       0.0)))),
        float(np.max(np.abs(np.nan_to_num(t_np.fleet_perf)
                            - np.nan_to_num(t_jx.fleet_perf)))),
        float(np.max(np.abs(np.nan_to_num(t_np.fleet_stale)
                            - np.nan_to_num(t_jx.fleet_stale)))))
    if t_np.fleet_times.shape == t_jx.fleet_times.shape:
        drift = max(drift,
                    float(np.max(np.abs(t_np.fleet_times - t_jx.fleet_times),
                                 initial=0.0)),
                    float(np.max(np.abs(t_np.fleet_model - t_jx.fleet_model),
                                 initial=0.0)))
    else:               # different action counts: report the count gap
        drift = max(drift, float(abs(t_np.fleet_times.shape[0]
                                     - t_jx.fleet_times.shape[0])))
    waves_agree = bool(t_np.waves == t_jx.waves)

    report = {
        "smoke": smoke,
        "horizon_s": horizon,
        "n_models": n_models,
        "n_pipelines": int(batched[0].summary["n_pipelines"]),
        "grid_points": len(points),
        "wall_batched_s": wall_batched,
        "wall_serial_s": wall_serial,
        "speedup_vs_serial": wall_serial / max(wall_batched, 1e-9),
        "n_triggered_total": int(sum(b.summary["n_triggered"]
                                     for b in batched)),
        "n_retrained_total": int(sum(b.summary["n_retrained"]
                                     for b in batched)),
        "frontier": frontier,
        "summary_batched_vs_serial_gap": summary_gap,
        "feedback_parity_drift": drift,
        "waves_agree": waves_agree,
    }
    os.makedirs(ART, exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)

    yield ("feedback_grid_batched", wall_batched * 1e6,
           f"{len(points)}pts_one_call")
    yield ("feedback_grid_serial", wall_serial * 1e6,
           f"speedup={report['speedup_vs_serial']:.2f}x")
    yield ("feedback_parity_drift", 0, drift)
    yield ("feedback_waves_agree", 0, waves_agree)
    yield ("feedback_retrains", 0, report["n_retrained_total"])


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    for row in rows():
        print(",".join(str(x) for x in row))
