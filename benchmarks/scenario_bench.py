"""Scenario-ensemble throughput: vmapped Monte-Carlo with per-replica
operational scenarios (capacity schedules + failure/retry tensors) vs the
static-capacity baseline — the cost of making the SPMD engine scenario-aware.

Emits ``artifacts/BENCH_scenarios.json`` so the perf trajectory is tracked
across PRs. ``REPRO_BENCH_SMOKE=1`` shrinks the workload for CI.

  PYTHONPATH=src python -m benchmarks.run scenarios
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import ART, fitted_params
from repro.core import vdes
from repro.core import model as M
from repro.core.synthesizer import synthesize_workload
from repro.ops import (FailureModel, OutageModel, Scenario,
                       ScheduledAutoscaler, stack_compiled_scenarios)

OUT_PATH = os.path.abspath(os.path.join(ART, "BENCH_scenarios.json"))


def _timed_ensemble(cols, caps, scen_kw):
    """Compile + one timed run of a single jit+vmap call."""
    args = [jax.numpy.asarray(c) for c in cols]
    caps = jax.numpy.asarray(caps)
    out = vdes.simulate_ensemble(*args, caps, **scen_kw)   # compile
    jax.block_until_ready(out["start"])
    t0 = time.perf_counter()
    out = vdes.simulate_ensemble(*args, caps, **scen_kw)
    jax.block_until_ready(out["start"])
    return time.perf_counter() - t0, out


def rows():
    smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    horizon = (0.25 if smoke else 1.0) * 86400.0
    R = 4 if smoke else 8
    params = fitted_params()
    plat = M.PlatformConfig()
    wl = synthesize_workload(params, jax.random.PRNGKey(17), horizon)
    n, T = wl.task_type.shape
    svc = wl.service_time(plat.datastore).astype(np.float32)
    cols = [np.tile(np.asarray(a)[None], (R,) + (1,) * np.asarray(a).ndim)
            for a in (wl.arrival.astype(np.float32), wl.n_tasks, wl.task_res,
                      svc, wl.priority)]
    caps = np.tile(plat.capacities[None], (R, 1)).astype(np.int32)

    wall_static, _ = _timed_ensemble(cols, caps, {})

    sc = Scenario(name="bench",
                  capacity=ScheduledAutoscaler(min_scale=0.5, max_scale=1.25),
                  failures=FailureModel(),
                  outages=OutageModel(mtbf_s=12 * 3600.0, mttr_s=3600.0))
    compiled = [sc.compile(wl, plat, horizon, seed=100 + r) for r in range(R)]
    scen_kw = stack_compiled_scenarios(compiled, n, horizon)
    wall_scen, out = _timed_ensemble(cols, caps, scen_kw)

    tput_static = R * n / wall_static
    tput_scen = R * n / wall_scen
    report = {
        "replicas": R,
        "pipelines_per_replica": n,
        "max_tasks": T,
        "schedule_changes": int(scen_kw["cap_times"].shape[1]),
        "static_wall_s": wall_static,
        "scenario_wall_s": wall_scen,
        "static_pipelines_per_s": tput_static,
        "scenario_pipelines_per_s": tput_scen,
        "scenario_overhead_x": wall_scen / max(wall_static, 1e-12),
        "smoke": smoke,
    }
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)

    return [
        (f"scenario_ensemble_static_{R}x{n}_pipelines_per_s",
         wall_static * 1e6, f"{tput_static:.0f}"),
        (f"scenario_ensemble_scenarios_{R}x{n}_pipelines_per_s",
         wall_scen * 1e6, f"{tput_scen:.0f}"),
        ("scenario_ensemble_overhead_x", wall_scen * 1e6,
         f"{report['scenario_overhead_x']:.2f}"),
    ]


def main():
    for r in rows():
        print(",".join(str(x) for x in r))
    print(f"# wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
