"""Fig 11 — dashboard scenario: when the learning cluster saturates around
the afternoon arrival peak, downstream (evaluate) tasks queue behind
long-running training jobs and pipeline wait inflates.

Reproduced as: two experiments differing only in learning-cluster capacity;
report utilization, queue-derived wait inflation, and the correlation between
learning-cluster saturation and evaluate-task delay."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import fitted_params, timeit_us
from repro.core import des
from repro.core import model as M
from repro.core.synthesizer import synthesize_workload
from repro.core.trace import (flatten_trace, mean_utilization,
                              utilization_timeline)


def rows():
    params = fitted_params()
    out = []
    horizon = 2 * 86400.0
    wl = synthesize_workload(params, jax.random.PRNGKey(42), horizon)

    recs = {}
    for cap, tag in ((64, "provisioned"), (6, "saturated")):
        plat = M.PlatformConfig(resources=(
            M.ResourceConfig("compute_cluster", 48),
            M.ResourceConfig("learning_cluster", cap)))
        us, tr = timeit_us(lambda p=plat: des.simulate(wl, p), repeat=1)
        rec = flatten_trace(tr, wl)
        recs[tag] = (rec, plat, us)
        util = mean_utilization(rec, plat.capacities, horizon)
        m_train = rec.task_type == M.TRAIN
        out.append((f"fig11_{tag}_learning_util", us, f"{util[1]:.3f}"))
        out.append((f"fig11_{tag}_train_wait_p95_s", us,
                    f"{np.percentile(rec.wait[m_train], 95):.1f}"))

    # Fig 11's causal story: learning-cluster saturation pushes evaluate
    # ARRIVALS (ready times) later — evaluate runs on the (uncongested)
    # compute cluster, so its own queueing wait stays ~0.
    (rs, plat_s, us) = recs["saturated"]
    (rp, _, _) = recs["provisioned"]
    m_eval_s = rs.task_type == M.EVALUATE
    m_eval_p = rp.task_type == M.EVALUATE
    # match per (pipeline, task_pos): same workload in both runs
    key_s = rs.pipeline[m_eval_s] * 10 + rs.task_pos[m_eval_s]
    key_p = rp.pipeline[m_eval_p] * 10 + rp.task_pos[m_eval_p]
    assert np.array_equal(np.sort(key_s), np.sort(key_p))
    order_s, order_p = np.argsort(key_s), np.argsort(key_p)
    delay = rs.ready[m_eval_s][order_s] - rp.ready[m_eval_p][order_p]
    out.append(("fig11_eval_arrival_delay_mean_s", us,
                f"{delay.mean():.1f}"))
    out.append(("fig11_eval_arrival_delay_p95_s", us,
                f"{np.percentile(delay, 95):.1f}"))

    # hourly learning utilization vs mean evaluate arrival delay
    ut = utilization_timeline(rs, plat_s.capacities, 3600.0, horizon)
    eva_hr = np.clip((rp.ready[m_eval_p][order_p] // 3600).astype(int), 0,
                     ut["util"].shape[1] - 1)
    nb = ut["util"].shape[1]
    sums = np.bincount(eva_hr, weights=delay, minlength=nb)
    cnts = np.maximum(np.bincount(eva_hr, minlength=nb), 1)
    r = np.corrcoef(ut["util"][1], sums / cnts)[0, 1]
    out.append(("fig11_saturation_vs_eval_delay_corr", us, f"{r:.3f}"))
    return out


def main():
    for r in rows():
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
