import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Scan-corrected cost audit for the roofline analysis.

XLA's ``compiled.cost_analysis()`` counts while/scan bodies ONCE regardless
of trip count, so the raw dry-run under-reports FLOPs/bytes/collective bytes
for scan-over-layers models. This audit reconstructs exact per-cell costs:

 1. compile 2-3 reduced-layer VARIANTS of each cell in *audit mode*
    (attn_q_chunk=0, stream_unroll=True, moe_token_chunks=1, microbatches=1:
    every streaming loop is either removed or unrolled, so cost_analysis is
    exact per variant);
 2. fit the per-stage linear model  cost = a + sum_s n_s * b_s  and
    reconstruct the full-config cost from the real stage counts;
 3. special-case the one remaining true recurrence (sLSTM over time):
    compile its step body once and add  (S-1) * per-step cost.

Artifacts: artifacts/roofline/<mesh>__<arch>__<shape>.json, consumed by
benchmarks/roofline.py and core/costmodel.py.
"""
import argparse
import json
import traceback
from typing import Dict, List, Tuple

import numpy as np

from repro.configs.shapes import SHAPES, cell_supported

AUDIT_BASE = {"attn_q_chunk": 0, "stream_unroll": True,
              "moe_token_chunks": 1, "microbatches": 1}


def _audit_base(arch: str) -> dict:
    base = dict(AUDIT_BASE)
    if arch == "xlstm-125m":
        # q_chunk is the mLSTM *algorithm* parameter (chunkwise form, §Perf
        # pair 3), not a streaming knob — keep the configured value and rely
        # on stream_unroll for exact counting of the chunk scan.
        base.pop("attn_q_chunk")
    return base

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                   "artifacts", "roofline"))


def _variants(arch: str) -> Tuple[List[Tuple[dict, dict]], Dict[str, float]]:
    """[(config overrides, stage counts)], full-config stage counts."""
    if arch == "deepseek-v3-671b":
        vs = [({"n_layers": 2, "n_dense_layers": 1}, {"d": 1, "m": 1}),
              ({"n_layers": 3, "n_dense_layers": 2}, {"d": 2, "m": 1}),
              ({"n_layers": 3, "n_dense_layers": 1}, {"d": 1, "m": 2})]
        return vs, {"d": 3, "m": 58}
    if arch == "llama4-maverick-400b-a17b":
        vs = [({"n_layers": 2}, {"s": 1}), ({"n_layers": 4}, {"s": 2})]
        return vs, {"s": 24}
    if arch == "llama-3.2-vision-90b":
        vs = [({"n_layers": 2, "cross_every": 2}, {"sf": 1, "cr": 1}),
              ({"n_layers": 3, "cross_every": 3}, {"sf": 2, "cr": 1}),
              ({"n_layers": 4, "cross_every": 2}, {"sf": 2, "cr": 2})]
        return vs, {"sf": 80, "cr": 20}
    if arch == "zamba2-1.2b":
        vs = [({"n_layers": 1, "attn_every": 1}, {"m": 1, "a": 1}),
              ({"n_layers": 2, "attn_every": 2}, {"m": 2, "a": 1}),
              ({"n_layers": 2, "attn_every": 1}, {"m": 2, "a": 2})]
        return vs, {"m": 38, "a": 6}
    if arch == "xlstm-125m":
        vs = [({"n_layers": 2}, {"s": 1}), ({"n_layers": 4}, {"s": 2})]
        return vs, {"s": 6}
    if arch == "seamless-m4t-large-v2":
        vs = [({"n_enc_layers": 1, "n_dec_layers": 1}, {"e": 1, "d": 1}),
              ({"n_enc_layers": 2, "n_dec_layers": 1}, {"e": 2, "d": 1}),
              ({"n_enc_layers": 1, "n_dec_layers": 2}, {"e": 1, "d": 2})]
        return vs, {"e": 24, "d": 24}
    # plain dense stacks
    vs = [({"n_layers": 1}, {"l": 1}), ({"n_layers": 2}, {"l": 2})]
    from repro import configs as CN
    L = CN.get_config(arch).n_layers
    return vs, {"l": L}


def _slstm_step_cost(arch: str, shape) -> Dict[str, float]:
    """Per-device per-timestep cost of the sLSTM recurrence (compiled
    standalone; batch is DP-sharded so divide the global step cost by the
    DP degree)."""
    import jax
    import jax.numpy as jnp
    from repro import configs as CN
    from repro.models import xlstm as XL

    cfg = CN.get_config(arch)
    B = shape.global_batch
    H, hd = cfg.n_heads, cfg.d_model // cfg.n_heads

    p, _ = XL.init_slstm(jax.random.PRNGKey(0), cfg.d_model, cfg.n_heads,
                         jnp.bfloat16)

    def step(c, n, h, m, xi, xf, xz, xo):
        ri = jnp.einsum("bhk,hkl->bhl", h, p["ri"])
        rf = jnp.einsum("bhk,hkl->bhl", h, p["rf"])
        rz = jnp.einsum("bhk,hkl->bhl", h, p["rz"])
        ro = jnp.einsum("bhk,hkl->bhl", h, p["ro"])
        li = (xi + ri).astype(jnp.float32)
        lf = jax.nn.log_sigmoid((xf + rf).astype(jnp.float32))
        m_new = jnp.maximum(lf + m, li)
        ig = jnp.exp(li - m_new)
        fg = jnp.exp(lf + m - m_new)
        z = jnp.tanh((xz + rz).astype(jnp.float32))
        o = jax.nn.sigmoid((xo + ro).astype(jnp.float32))
        c_new = fg * c + ig * z
        n_new = fg * n + ig
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return c_new, n_new, h_new, m_new

    f32 = lambda: jax.ShapeDtypeStruct((B, H, hd), jnp.float32)
    bf = lambda: jax.ShapeDtypeStruct((B, H, hd), jnp.bfloat16)
    c = jax.jit(step).lower(f32(), f32(), f32(), f32(),
                            bf(), bf(), bf(), bf()).compile()
    ca = c.cost_analysis()
    dp = 16  # batch shards over 'data' on both production meshes
    return {"flops": float(ca.get("flops", 0.0)) / dp,
            "bytes": float(ca.get("bytes accessed", 0.0)) / dp,
            "coll": 0.0}


def audit_cell(arch: str, shape_name: str, mesh_name: str = "single",
               extra_overrides: Dict = None) -> Dict:
    from repro import configs as CN
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_production_mesh

    cfg0 = CN.get_config(arch)
    spec = SHAPES[shape_name]
    ok, reason = cell_supported(cfg0.family, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "skip_reason": reason}

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    variants, full_counts = _variants(arch)
    names = sorted(full_counts)
    rows = []
    targets = {"flops": [], "bytes": [], "coll": []}
    var_recs = []
    for overrides, counts in variants:
        ov = _audit_base(arch)
        ov.update(extra_overrides or {})
        ov.update(overrides)
        rec = lower_cell(arch, shape_name, mesh, mesh_name, ov)
        if rec.get("status") != "ok":
            return {"arch": arch, "shape": shape_name, "status": "error",
                    "error": rec.get("error", "variant failed"),
                    "variant": overrides}
        rows.append([1.0] + [float(counts.get(n, 0)) for n in names])
        targets["flops"].append(rec["flops_per_device"])
        targets["bytes"].append(rec["bytes_accessed_per_device"])
        targets["coll"].append(sum(v["bytes"]
                                   for v in rec["collectives"].values()))
        var_recs.append({"overrides": {k: v for k, v in overrides.items()},
                         "flops": rec["flops_per_device"],
                         "coll": targets["coll"][-1],
                         "compile_s": rec["compile_s"]})

    A = np.asarray(rows)
    full_vec = np.asarray([1.0] + [float(full_counts[n]) for n in names])
    out = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "ok", "stage_names": names, "variants": var_recs}
    resid = {}
    for key, tgt in targets.items():
        y = np.asarray(tgt)
        coef, res, *_ = np.linalg.lstsq(A, y, rcond=None)
        # guard: per-stage costs are physically non-negative; tiny variants
        # can show inverted slopes from XLA layout choices at L=1.
        if np.any(coef[1:] < 0):
            coef[1:] = np.maximum(coef[1:], 0.0)
            coef[0] = float(np.mean(y - A[:, 1:] @ coef[1:]))
        recon = float(np.dot(full_vec, coef))
        resid[key] = float(res[0]) if len(res) else 0.0
        out[{"flops": "flops_per_device", "bytes": "bytes_per_device",
             "coll": "collective_bytes_per_device"}[key]] = max(recon, 0.0)
        out.setdefault("stage_coeffs", {})[key] = {
            "base": float(coef[0]),
            **{n: float(c) for n, c in zip(names, coef[1:])}}

    # sLSTM time-recurrence correction
    if arch == "xlstm-125m" and spec.kind in ("train", "prefill"):
        step_cost = _slstm_step_cost(arch, spec)
        S = spec.seq_len
        n_supers = full_counts["s"]
        out["flops_per_device"] += step_cost["flops"] * (S - 1) * n_supers
        out["bytes_per_device"] += step_cost["bytes"] * (S - 1) * n_supers
        out["slstm_step_flops_per_device"] = step_cost["flops"]

    out["fit_residual"] = resid
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="extra config override k=v (perf experiments)")
    ap.add_argument("--tag", default=None)
    args = ap.parse_args()

    import ast
    extra = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            extra[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            extra[k] = v

    from repro import configs as CN
    archs = [args.arch] if args.arch else CN.ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    os.makedirs(ART, exist_ok=True)
    for arch in archs:
        for shape_name in shapes:
            suffix = f"__{args.tag}" if args.tag else ""
            path = os.path.join(ART,
                                f"{args.mesh}__{arch}__{shape_name}{suffix}.json")
            if os.path.exists(path) and not args.force:
                print(f"[cached] {arch} x {shape_name}")
                continue
            print(f"[audit] {arch} x {shape_name} ...", flush=True)
            try:
                rec = audit_cell(arch, shape_name, args.mesh, extra)
            except Exception as e:
                rec = {"arch": arch, "shape": shape_name, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]}
            with open(path, "w") as f:
                json.dump(rec, f, indent=2)
            if rec["status"] == "ok":
                print(f"  -> flops/dev={rec['flops_per_device']:.3e} "
                      f"coll/dev={rec['collective_bytes_per_device']:.3e}",
                      flush=True)
            else:
                print(f"  -> {rec['status']}: {rec.get('error', '')[:150]}",
                      flush=True)


if __name__ == "__main__":
    main()
