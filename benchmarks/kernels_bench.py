"""Kernel microbenchmarks + the kernel/compaction parity artifact.

Two halves, one module:

  1. **Reference-kernel rows** (full mode only): interpret-mode parity
     timing is meaningless for perf, so we report the jnp-reference wall
     time (the XLA path the dry-run uses) plus analytic kernel arithmetic
     intensities for the §Roofline story.
  2. **``artifacts/BENCH_kernels.json``** (always, and the whole smoke
     run): the wave-loop fast-path parity gate —
     ``pallas_vs_lax_admission_drift`` (the fused Pallas admission kernel
     vs the ``lax.sort`` ranking vs the dense pairwise mask, random rounds
     with heavy ties; integer mask compare, must be exactly 0.0),
     ``compaction_vs_uncompacted_drift`` (the windowed compaction driver
     vs the plain batched ensemble over every result tensor, exactly
     0.0), and compaction on/off walls + waves/s at three ensemble
     widths. ``benchmarks.check_drift`` fails ``make ci`` if either drift
     key is nonzero or the artifact is missing.

  PYTHONPATH=src python -m benchmarks.run kernels
  PYTHONPATH=src python benchmarks/kernels_bench.py --smoke
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                "..")))

from benchmarks.common import ART, timeit_us
from repro.core import batching, compaction, vdes
from repro.core import model as M
from repro.kernels import ref
from repro.kernels.queue_scan import fused_admission

OUT_PATH = os.path.abspath(os.path.join(ART, "BENCH_kernels.json"))


def _ref_kernel_rows():
    out = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)

    B, S, H, D = 4, 1024, 8, 128
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    jax.block_until_ready(f(q, k, v))
    us, _ = timeit_us(lambda: jax.block_until_ready(f(q, k, v)))
    flops = 4 * B * H * S * S * D
    out.append(("kernel_attention_ref_1k_gflops_per_s", us,
                f"{flops / us / 1e3:.1f}"))
    # arithmetic intensity of the flash kernel working set
    ai = flops / ((3 * B * S * H * D + B * S * H * D) * 2)
    out.append(("kernel_attention_arith_intensity_flops_per_byte", us,
                f"{ai:.0f}"))

    Bz, S2, Hm, P, N = 4, 1024, 8, 64, 64
    x = jax.random.normal(ks[0], (Bz, S2, Hm, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, S2, Hm))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (Hm,)) * 0.3)
    Bm = jax.random.normal(ks[3], (Bz, S2, N)) * 0.3
    Cm = jax.random.normal(ks[4], (Bz, S2, N)) * 0.3
    g = jax.jit(lambda *a: ref.mamba2_scan_ref(*a)[0])
    jax.block_until_ready(g(x, dt, A, Bm, Cm))
    us, _ = timeit_us(lambda: jax.block_until_ready(g(x, dt, A, Bm, Cm)))
    chunk = 128
    flops_ssd = 2 * Bz * (S2 * chunk * (N + Hm * P) + S2 * Hm * P * N * 2)
    out.append(("kernel_mamba2_ref_1k_gflops_per_s", us,
                f"{flops_ssd / us / 1e3:.2f}"))

    R, NJ, c = 64, 2048, 8
    rng = np.random.default_rng(0)
    rdy = jnp.asarray(np.sort(rng.uniform(0, 1e5, (R, NJ)), 1), jnp.float32)
    svc = jnp.asarray(rng.exponential(30.0, (R, NJ)), jnp.float32)
    h = jax.jit(lambda r, s: ref.queue_scan_ref(r, s, capacity=c)[0])
    jax.block_until_ready(h(rdy, svc))
    us, _ = timeit_us(lambda: jax.block_until_ready(h(rdy, svc)))
    out.append(("kernel_queue_scan_jobs_per_s", us,
                f"{R * NJ / (us / 1e6):.0f}"))
    return out


# ------------------------------------------- admission/compaction parity

def _admission_drift(n_rounds: int = 24) -> float:
    """Max |pallas - lax| over the admitted masks of random admission
    rounds (heavy ties in every key, sentinel rows included). The three
    production paths — the Pallas kernel (interpreted off-TPU), the fused
    ``lax.sort`` seat test, and the dense pairwise mask — must agree
    bit-for-bit; the compare is integer, so any disagreement shows up as
    exactly 1.0, never float noise."""
    drift = 0.0
    g = np.random.default_rng(20260807)
    for i in range(n_rounds):
        n = int(g.integers(1, 300))
        nres = int(g.integers(1, 4))
        res_q = g.integers(0, nres + 1, n).astype(np.int32)
        pkey = g.integers(0, 3, n).astype(np.float32)
        wave = g.integers(0, 4, n).astype(np.int32)
        free = g.integers(0, max(2, n // 2), nres).astype(np.int32)
        a_pl = np.asarray(fused_admission(res_q, pkey, wave, free))
        a_dn = np.asarray(vdes.admission_mask_dense(res_q, pkey, wave, free))
        r_s, o = (np.asarray(a) for a in
                  vdes.admission_order(res_q, pkey, wave))
        pos = np.arange(n)
        seg = np.maximum.accumulate(
            np.where(np.r_[True, r_s[1:] != r_s[:-1]], pos, -1))
        a_lx = np.zeros(n, bool)
        a_lx[o] = (pos - seg) < np.r_[free, 0][r_s]
        drift = max(drift,
                    float(np.max(np.abs(a_pl.astype(int) - a_lx.astype(int)),
                                 initial=0.0)),
                    float(np.max(np.abs(a_dn.astype(int) - a_lx.astype(int)),
                                 initial=0.0)))
    return drift


def _workload(g, n, max_tasks=4, horizon=500.0):
    """Random integer-time workload (same recipe as the engine twin tests:
    integer times are exactly representable in f32, so the drift compare
    is parity, not float noise)."""
    n_tasks = g.integers(1, max_tasks + 1, n)
    task_type = np.where(np.arange(max_tasks)[None, :] < n_tasks[:, None],
                         g.integers(0, 2, (n, max_tasks)), -1)
    return M.Workload(
        arrival=np.floor(np.sort(g.uniform(0, horizon, n))),
        n_tasks=n_tasks.astype(np.int32),
        task_type=task_type.astype(np.int32),
        task_res=(g.integers(0, 2, (n, max_tasks))
                  * (task_type >= 0)).astype(np.int32),
        exec_time=np.ceil(g.exponential(20.0, (n, max_tasks)))
        * (task_type >= 0),
        read_bytes=np.zeros((n, max_tasks)),
        write_bytes=np.zeros((n, max_tasks)),
        framework=g.integers(0, 5, n).astype(np.int32),
        priority=g.uniform(0, 1, n).astype(np.float32),
        model_perf=np.zeros(n, np.float32),
        model_size=np.zeros(n, np.float32),
        model_clever=np.zeros(n, np.float32),
    )


def _ensemble(widths):
    """A congested little ensemble (tight caps -> long queues) with
    replica-distinct integer workloads, padded to the max width."""
    g = np.random.default_rng(7)
    B = max(widths)
    plat = M.PlatformConfig(resources=(M.ResourceConfig("a", 3),
                                       M.ResourceConfig("b", 2)))
    # enough rows/waves that the working set actually shrinks over the
    # run — at toy sizes the driver's boundary overhead wins instead
    wls = [_workload(g, 140 - 4 * i, horizon=1500.0) for i in range(B)]
    cols = batching.pad_workloads(wls, plat)
    cols.pop("n_max")
    caps = np.tile(np.asarray(plat.capacities, np.int32)[None], (B, 1))
    return cols, caps


def _compaction_section(widths):
    cols, caps = _ensemble(widths)
    walls_on, walls_off, waves_ps = {}, {}, {}
    drift = 0.0
    segs = 0
    for B in widths:
        args_np = [np.asarray(cols[k])[:B] for k in
                   ("arrival", "n_tasks", "task_res", "service", "priority")]
        args = [jnp.asarray(a) for a in args_np]
        caps_b = jnp.asarray(caps[:B])
        out_off = vdes.simulate_ensemble(*args, caps_b,
                                         admission_sort="dense")  # compile
        jax.block_until_ready(out_off["start"])
        t0 = time.perf_counter()
        out_off = vdes.simulate_ensemble(*args, caps_b,
                                         admission_sort="dense")
        jax.block_until_ready(out_off["start"])
        walls_off[B] = time.perf_counter() - t0

        log = compaction.CompactionLog()
        out_on = compaction.simulate_ensemble_compacted(
            *args_np, caps[:B], admission_sort="dense", log=log)  # warm
        best = np.inf
        for _ in range(3):
            t0 = time.perf_counter()
            out_on = compaction.simulate_ensemble_compacted(
                *args_np, caps[:B], admission_sort="dense")
            best = min(best, time.perf_counter() - t0)
        walls_on[B] = best
        segs = log.n_segments
        waves_ps[B] = float(np.sum(out_on["waves"])) / max(best, 1e-12)
        for k, v in out_on.items():
            drift = max(drift, float(np.max(np.abs(
                np.nan_to_num(np.asarray(v, np.float64))
                - np.nan_to_num(np.asarray(out_off[k], np.float64))),
                initial=0.0)))
    return walls_on, walls_off, waves_ps, drift, segs


def rows():
    smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    widths = (2, 4, 8)
    adm_drift = _admission_drift()
    walls_on, walls_off, waves_ps, comp_drift, segs = \
        _compaction_section(widths)
    b_max = widths[-1]
    speedup = walls_off[b_max] / max(walls_on[b_max], 1e-12)

    report = {
        "pallas_vs_lax_admission_drift": adm_drift,
        "compaction_vs_uncompacted_drift": comp_drift,
        "compaction_wall_by_width_s": {str(k): v
                                       for k, v in walls_on.items()},
        "uncompacted_wall_by_width_s": {str(k): v
                                        for k, v in walls_off.items()},
        "compaction_waves_per_s_by_width": {str(k): v
                                            for k, v in waves_ps.items()},
        "compaction_speedup_x": speedup,
        "compaction_segments": segs,
        "widths": list(widths),
        "smoke": smoke,
    }
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)

    out = [
        ("kernel_pallas_admission_drift", adm_drift * 1e6, f"{adm_drift}"),
        ("kernel_compaction_drift", comp_drift * 1e6, f"{comp_drift}"),
        ("kernel_compaction_wall", walls_on[b_max] * 1e6,
         f"{speedup:.2f}x_vs_uncompacted_B{b_max}"),
        ("kernel_compaction_waves", walls_off[b_max] * 1e6,
         f"{waves_ps[b_max]:.0f}waves/s"),
    ]
    if not smoke:
        out = _ref_kernel_rows() + out
    return out


def main():
    if "--smoke" in sys.argv[1:]:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    for r in rows():
        print(",".join(str(x) for x in r))
    print(f"# wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
