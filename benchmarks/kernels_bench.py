"""Kernel microbenchmarks: interpret-mode parity timing is meaningless for
perf, so we report the jnp-reference wall time (the XLA path the dry-run
uses) plus analytic kernel arithmetic intensities for the §Roofline story."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit_us
from repro.kernels import ref


def rows():
    out = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)

    B, S, H, D = 4, 1024, 8, 128
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    jax.block_until_ready(f(q, k, v))
    us, _ = timeit_us(lambda: jax.block_until_ready(f(q, k, v)))
    flops = 4 * B * H * S * S * D
    out.append(("kernel_attention_ref_1k_gflops_per_s", us,
                f"{flops / us / 1e3:.1f}"))
    # arithmetic intensity of the flash kernel working set
    ai = flops / ((3 * B * S * H * D + B * S * H * D) * 2)
    out.append(("kernel_attention_arith_intensity_flops_per_byte", us,
                f"{ai:.0f}"))

    Bz, S2, Hm, P, N = 4, 1024, 8, 64, 64
    x = jax.random.normal(ks[0], (Bz, S2, Hm, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, S2, Hm))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (Hm,)) * 0.3)
    Bm = jax.random.normal(ks[3], (Bz, S2, N)) * 0.3
    Cm = jax.random.normal(ks[4], (Bz, S2, N)) * 0.3
    g = jax.jit(lambda *a: ref.mamba2_scan_ref(*a)[0])
    jax.block_until_ready(g(x, dt, A, Bm, Cm))
    us, _ = timeit_us(lambda: jax.block_until_ready(g(x, dt, A, Bm, Cm)))
    chunk = 128
    flops_ssd = 2 * Bz * (S2 * chunk * (N + Hm * P) + S2 * Hm * P * N * 2)
    out.append(("kernel_mamba2_ref_1k_gflops_per_s", us,
                f"{flops_ssd / us / 1e3:.2f}"))

    R, NJ, c = 64, 2048, 8
    rng = np.random.default_rng(0)
    rdy = jnp.asarray(np.sort(rng.uniform(0, 1e5, (R, NJ)), 1), jnp.float32)
    svc = jnp.asarray(rng.exponential(30.0, (R, NJ)), jnp.float32)
    h = jax.jit(lambda r, s: ref.queue_scan_ref(r, s, capacity=c)[0])
    jax.block_until_ready(h(rdy, svc))
    us, _ = timeit_us(lambda: jax.block_until_ready(h(rdy, svc)))
    out.append(("kernel_queue_scan_jobs_per_s", us,
                f"{R * NJ / (us / 1e6):.0f}"))
    return out


def main():
    for r in rows():
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
