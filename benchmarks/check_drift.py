"""CI gate: fail when any benchmark artifact reports numpy-vs-jax drift.

Scans every ``artifacts/BENCH_*.json`` for keys containing ``drift`` (e.g.
``numpy_vs_jax_drift``, ``realized_timeline_drift``,
``max_rel_drift_vs_serial``) and exits nonzero if any value is not exactly
0.0 — so an engine-parity regression cannot land silently just because the
benchmark that measured it "succeeded". Run by ``make ci`` after the smoke
benchmarks refresh the artifacts.

  PYTHONPATH=src python -m benchmarks.check_drift
"""
from __future__ import annotations

import glob
import json
import os
import sys

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                   "artifacts"))


def check(art_dir: str = ART) -> list:
    """Return a list of ``(file, key, value)`` offenders with nonzero drift."""
    bad = []
    for path in sorted(glob.glob(os.path.join(art_dir, "BENCH_*.json"))):
        with open(path) as f:
            report = json.load(f)
        for key, val in report.items():
            if "drift" not in key:
                continue
            if not isinstance(val, (int, float)) or val != 0.0:
                bad.append((os.path.basename(path), key, val))
    return bad


def main() -> None:
    offenders = check()
    if offenders:
        for fname, key, val in offenders:
            print(f"DRIFT {fname}: {key} = {val!r} (expected 0.0)",
                  file=sys.stderr)
        sys.exit(1)
    print("drift check: all BENCH_*.json artifacts report 0.0 drift")


if __name__ == "__main__":
    main()
