"""CI gate: fail on numpy-vs-jax drift, a missing benchmark artifact, OR
an unbaselined static-analysis finding.

Scans every ``artifacts/BENCH_*.json`` for keys containing ``drift`` (e.g.
``numpy_vs_jax_drift``, ``realized_timeline_drift``, ``probe_parity_drift``,
``max_rel_drift_vs_serial``) and exits nonzero if any value is not exactly
0.0 — so an engine-parity regression cannot land silently just because the
benchmark that measured it "succeeded". It also requires every smoke-suite
artifact in ``EXPECTED`` to exist: a bench that errors out used to leave a
stale (or no) artifact undetected — now a missing file fails the build the
same way drift does. ``artifacts/ANALYSIS.json`` (written by ``make lint``,
the parity auditor) is an expected artifact too, and a nonzero
``n_unbaselined`` in it fails the build — the static gate and the runtime
parity gate land in the same place. Run by ``make ci`` after ``make lint``
and the smoke benchmarks refresh the artifacts.

  PYTHONPATH=src python -m benchmarks.check_drift
"""
from __future__ import annotations

import glob
import json
import os
import sys

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                   "artifacts"))

# every artifact the `make ci` smoke suites must produce (keep in sync with
# benchmarks/run.py SMOKE_SUITES and each suite's OUT_PATH)
EXPECTED = (
    "BENCH_scenarios.json",
    "BENCH_sweep.json",
    "BENCH_controller.json",
    "BENCH_feedback.json",
    "BENCH_obs.json",
    "BENCH_kernels.json",
    "BENCH_stream.json",
    "BENCH_reliability.json",
    # written by `make lint` (python -m repro.analysis), not by a bench
    "ANALYSIS.json",
)


def missing(art_dir: str = ART) -> list:
    """Expected artifacts absent from ``art_dir``."""
    return [name for name in EXPECTED
            if not os.path.exists(os.path.join(art_dir, name))]


def check(art_dir: str = ART) -> list:
    """Return a list of ``(file, key, value)`` offenders with nonzero drift."""
    bad = []
    for path in sorted(glob.glob(os.path.join(art_dir, "BENCH_*.json"))):
        with open(path) as f:
            report = json.load(f)
        for key, val in report.items():
            if "drift" not in key:
                continue
            if not isinstance(val, (int, float)) or val != 0.0:
                bad.append((os.path.basename(path), key, val))
    return bad


def check_analysis(art_dir: str = ART) -> list:
    """``(file, key, value)`` offenders from the static-analysis report."""
    path = os.path.join(art_dir, "ANALYSIS.json")
    if not os.path.exists(path):
        return []                      # absence is reported by missing()
    with open(path) as f:
        report = json.load(f)
    n = report.get("n_unbaselined")
    if n == 0:
        return []
    return [("ANALYSIS.json", "n_unbaselined", n)]


def main() -> None:
    gone = missing()
    offenders = check()
    analysis_bad = check_analysis()
    for name in gone:
        print(f"MISSING artifacts/{name}: its benchmark did not run or "
              f"errored out", file=sys.stderr)
    for fname, key, val in offenders:
        print(f"DRIFT {fname}: {key} = {val!r} (expected 0.0)",
              file=sys.stderr)
    for fname, key, val in analysis_bad:
        print(f"ANALYSIS {fname}: {key} = {val!r} (expected 0) — run "
              "`make lint` for the findings", file=sys.stderr)
    if gone or offenders or analysis_bad:
        sys.exit(1)
    print(f"drift check: all {len(EXPECTED)} expected artifacts present, "
          "all drift keys 0.0, 0 unbaselined analysis findings")


if __name__ == "__main__":
    main()
