"""Shared benchmark fixtures: cached empirical traces + fitted params."""
from __future__ import annotations

import os
import time

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
PARAMS_PATH = os.path.abspath(os.path.join(ART, "pipesim_params.npz"))

_cache = {}


def empirical_workload(days: float = 14.0, seed: int = 123):
    """Two weeks of traces: every hour-of-week cluster (incl. weekends) gets
    enough samples for its own fit — 3-day fits degenerate weekend clusters
    to the global fallback and wreck the clustered-profile benchmarks."""
    key = ("wl", days, seed)
    if key not in _cache:
        from repro.core.workload import generate_empirical_workload
        _cache[key] = generate_empirical_workload(
            seed=seed, horizon_s=days * 86400.0)
    return _cache[key]


def fitted_params(days: float = 14.0, seed: int = 123):
    if "params" in _cache:
        return _cache["params"]
    from repro.core.fitting import SimulationParams, fit_simulation_params
    os.makedirs(os.path.dirname(PARAMS_PATH), exist_ok=True)
    if os.path.exists(PARAMS_PATH):
        _cache["params"] = SimulationParams.load(PARAMS_PATH)
        return _cache["params"]
    wl = empirical_workload(days, seed)
    t0 = time.perf_counter()
    params = fit_simulation_params(wl)
    print(f"# fitted simulation params on {wl.n} pipelines in "
          f"{time.perf_counter() - t0:.1f}s")
    params.save(PARAMS_PATH)
    _cache["params"] = params
    return params


def timeit_us(fn, *args, repeat: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, out
