"""Availability-vs-cost frontier under correlated failures (AIReSim-style):
sweep the spot-pool share against repair-crew capacity and read the
trade-off straight out of each point's ``availability`` summary block.

A bigger spot pool is cheaper (``discount`` x on-demand) but loses more
capacity to mass evictions; more repair crews return failed domains
faster (capacity comes back at the crew's FIFO *finish* time, never
instantaneously) but add standing cost you can price however you like.
The ``"reliability:*"`` sweep axes batch like every other axis — the
whole 4 x 3 grid below lowers to ONE jit+vmap ``simulate_ensemble`` call,
reliability-free points riding the same batch via never-firing padding
rows.

  PYTHONPATH=src python examples/reliability_frontier.py
"""
import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

from benchmarks.common import fitted_params
from repro.core.experiment import ExperimentSpec, Sweep
from repro.reliability import (DomainOutageModel, ReliabilitySpec,
                               RepairSpec, SpotPoolSpec, TopologySpec)

params = fitted_params()
HORIZON = 43200.0

base = ExperimentSpec(
    name="frontier", horizon_s=HORIZON, engine="jax", seed=7,
    reliability=ReliabilitySpec(
        topology=TopologySpec(zones=2, racks_per_zone=4),
        outages=DomainOutageModel(zone_mtbf_s=HORIZON / 2.0,
                                  rack_mtbf_s=HORIZON / 4.0,
                                  mttr_s=HORIZON / 24.0),
        time_quantum_s=1.0))

SPOTS = [None] + [SpotPoolSpec(frac=f, evict_mtbe_s=HORIZON / 3.0,
                               reclaim_s=HORIZON / 48.0) for f in
                  (0.2, 0.4, 0.6)]
CREWS = [RepairSpec(crews=c, repair_time_s=HORIZON / 24.0) for c in (1, 2, 6)]

results = Sweep(base, {"reliability:spot": SPOTS,
                       "reliability:repair": CREWS}).run(params)

print(f"{'spot frac':>9} {'crews':>5} {'avail':>7} {'cost':>10} "
      f"{'savings':>9} {'max wait s':>10} {'evicted':>7}")
for (spot, crew), res in zip(((s, c) for s in SPOTS for c in CREWS), results):
    a = res.summary["availability"]
    cost = a["cost_split"]["on_demand_cost"] + a["cost_split"]["spot_cost"]
    print(f"{(spot.frac if spot else 0.0):9.1f} {crew.crews:5d} "
          f"{min(a['availability'].values()):7.3f} {cost:10.0f} "
          f"{a['cost_split']['spot_savings']:9.0f} "
          f"{a['repair']['max_wait_s']:10.0f} "
          f"{a['eviction']['evicted_tasks'] if 'eviction' in a else 0:7d}")

print("\nThe frontier: walk down the cost column until availability drops "
      "below your SLO; adding crews buys back availability at the "
      "saturated (1-crew) points where max repair wait explodes.")
