"""Quickstart: the PipeSim loop in ~40 lines.

1. Generate empirical platform traces (the "real system");
2. fit simulation parameters (GMMs, duration curves, clustered arrivals);
3. synthesize a workload and simulate it on a modeled platform;
4. read the analytics.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import (PlatformConfig, ResourceConfig, des,
                        fit_simulation_params, generate_empirical_workload,
                        synthesize_workload)
from repro.core.trace import flatten_trace, summarize

# 1. two days of "production" traces
wl = generate_empirical_workload(seed=0, horizon_s=2 * 86400.0)
print(f"empirical traces: {wl.n} pipelines, "
      f"mean interarrival {np.diff(np.sort(wl.arrival)).mean():.1f}s")

# 2. fit -> export (the paper's scipy/scikit-learn offline step, in JAX)
params = fit_simulation_params(wl, interarrival_families=(0,),
                               asset_components=16, em_iters=30,
                               max_cluster_fit_n=500)

# 3. simulate one day on a smaller platform than production
platform = PlatformConfig(resources=(
    ResourceConfig("compute_cluster", 24),
    ResourceConfig("learning_cluster", 12)))
syn = synthesize_workload(params, jax.random.PRNGKey(1),
                          horizon_s=86400.0, platform=platform)
trace = des.simulate(syn, platform)

# 4. analytics (the dashboard numbers)
rec = flatten_trace(trace, syn)
import json
print(json.dumps(summarize(rec, platform.capacities, 86400.0), indent=2,
                 default=float))
