"""Capacity planning (paper §VI-A / Fig 11): sweep learning-cluster capacity
against the fitted workload and find the knee where queueing collapses —
with Monte-Carlo confidence intervals from the vmapped JAX engine.

The ``"capacity:<resource>"`` sweep axis resizes one pool of the platform
(works for any resource count); with ``engine="jax"`` the whole grid — five
capacities x four replicas each — runs as ONE jit+vmap call.

  PYTHONPATH=src python examples/capacity_planning.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

from benchmarks.common import fitted_params
from repro.core.experiment import ExperimentSpec, Sweep

params = fitted_params()

base = ExperimentSpec(name="cap", horizon_s=43200.0, engine="jax",
                      n_replicas=4, seed=7)
results = Sweep(base, {"capacity:learning_cluster": [4, 8, 16, 32, 64]}).run(
    params)

print(f"{'capacity':>9} {'util':>6} {'mean wait s':>12} "
      f"{'p95 wait s':>11} {'ci95':>8}")
for cap, res in zip((4, 8, 16, 32, 64), results):
    s = res.summary
    util = np.mean([r["utilization"]["learning_cluster"]
                    for r in res.replica_summaries])
    print(f"{cap:9d} {util:6.2f} {s['mean_wait_s']:12.1f} "
          f"{s['p95_wait_s']:11.1f} {s['wait_ci95_halfwidth']:8.2f}")

print("\nPick the smallest capacity whose p95 wait meets the SLA — the "
      "simulated knee is where utilization crosses ~0.85.")
