"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on the synthetic data pipeline, with checkpointing and an
injected fault + restart mid-run.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import json

from repro.launch.train import run_training

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

# smoke=True scales the config down to ~100M-class dims runnable on CPU;
# pass a full config on real hardware.
out = run_training(
    "llama3.2-1b",
    steps=args.steps, batch=8, seq=256, smoke=True,
    ckpt_dir=args.ckpt_dir, ckpt_every=50, fault_at=[args.steps // 2],
    lr=1e-3, log_every=20)

first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
print(json.dumps({"first_loss": first, "last_loss": last,
                  "improved": last < first, "restarts": out["restarts"]},
                 indent=2))
assert last < first, "training did not reduce loss"
