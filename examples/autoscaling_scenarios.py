"""Operational scenario A/B: static capacity vs maintenance windows vs
predictive (hour-of-week) and reactive (queue-length) autoscalers, with
failure/retry injection and node outages — comparing p95 wait, deadline-miss
rate, and provisioned cost (the paper's "devise and evaluate operational
strategies", extended with AIReSim-style reliability).

Written against the declarative API: an :class:`ExperimentSpec` carries the
full platform (any number of resources, each with its own cost), and
``Sweep`` runs the scenario axis as one grid — serially on the exact numpy
engine here; switch the base to ``engine="jax"`` and the whole grid lowers
to ONE jit+vmap call (see benchmarks/sweep_bench.py).

  PYTHONPATH=src python examples/autoscaling_scenarios.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

from benchmarks.common import fitted_params
from repro.core.experiment import ExperimentSpec, Sweep
from repro.core.model import PlatformConfig, ResourceConfig
from repro.ops import (FailureModel, MaintenanceWindows, OutageModel,
                       ReactiveAutoscaler, Scenario, ScheduledAutoscaler,
                       SLOConfig)

params = fitted_params()
HORIZON = 86400.0
slo = SLOConfig(pipeline_deadline_s=4 * 3600.0, task_wait_slo_s=900.0)
fails = FailureModel(resample_service=True)   # retries re-draw durations

SCENARIOS = [
    Scenario(name="static", slo=slo, failures=fails),
    Scenario(name="maintenance", slo=slo, failures=fails,
             capacity=MaintenanceWindows(
                 windows=((2 * 3600.0, 6 * 3600.0, 1, 0.25),))),
    Scenario(name="outages", slo=slo, failures=fails,
             outages=OutageModel(mtbf_s=8 * 3600.0, mttr_s=3600.0,
                                 frac_lost=0.33)),
    Scenario(name="predictive", slo=slo, failures=fails,
             capacity=ScheduledAutoscaler(min_scale=0.4, max_scale=1.3)),
    Scenario(name="reactive", slo=slo, failures=fails,
             capacity=ReactiveAutoscaler(interval_s=3600.0, max_scale=2.0,
                                         min_scale=0.4)),
]

base = ExperimentSpec(
    name="ops", horizon_s=HORIZON, seed=7,
    platform=PlatformConfig(resources=(
        ResourceConfig("compute_cluster", 48, cost_per_node_hour=1.0),
        ResourceConfig("learning_cluster", 16, cost_per_node_hour=3.0),
    )))
results = Sweep(base, {"scenario": SCENARIOS}).run(params)

print(f"{'scenario':>12} {'p95 wait s':>11} {'miss rate':>10} "
      f"{'wait SLO viol':>13} {'cost $':>9} {'util(prov)':>10}")
for sc, res in zip(SCENARIOS, results):
    s = res.summary
    util = np.mean(list(s["utilization_vs_provisioned"].values()))
    print(f"{sc.name:>12} {s['p95_wait_s']:11.1f} "
          f"{s['deadline_miss_rate']:10.3f} "
          f"{s['wait_slo_violation_rate']:13.3f} {s['total_cost']:9.1f} "
          f"{util:10.2f}")

print("\nThe autoscalers trade provisioned cost against wait/deadline SLOs; "
      "outages show the resilience margin. Cross this axis with capacities "
      "and schedulers — base.with_(engine='jax') compiles the whole grid "
      "into one SPMD call.")
