"""Trace-driven replay, end to end: export a run as spans, rebuild the
workload from the span file alone, replay it bit-exactly, then answer a
what-if against the *same observed demand*.

Exact replay holds on the integer-time configuration with
``resample_service=False`` (service is a pure function of the task, so
re-simulating reproduces every attempt window to the float32 ulp). The
spans are the only thing that crosses the boundary: the replay side never
sees the original ``Workload`` — :class:`repro.stream.SpanSource` derives
arrivals, service times, task types, and per-attempt retry counts from the
JSONL file that a real platform's tracing pipeline would emit.

  PYTHONPATH=src python examples/replay_trace.py
"""
import dataclasses
import os
import sys

import numpy as np

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                "..")))

import jax

from benchmarks.common import ART, fitted_params
from repro.core import model as M
from repro.core.synthesizer import synthesize_workload
from repro.obs import attempt_intervals_from_records, build_spans
from repro.obs.spans import attempt_intervals, write_spans_jsonl
from repro.ops import FailureModel, ReactiveController, RetryPolicy, Scenario
from repro.stream import (SpanSource, oneshot_reference, parity_drift,
                          stream_simulate)

HORIZON = 0.25 * 86400.0
SPAN_PATH = os.path.join(ART, "replay_spans.jsonl")


class BlockSource:
    """A pinned workload served as arrival-ordered blocks (a TraceSource)."""

    name = "replay-example"

    def __init__(self, wl, block=64):
        self.wl, self.block = wl, block

    def blocks(self):
        for lo in range(0, self.wl.arrival.shape[0], self.block):
            hi = min(lo + self.block, self.wl.arrival.shape[0])
            yield M.Workload(**{
                f.name: (v[lo:hi] if isinstance(
                    v := getattr(self.wl, f.name), np.ndarray) else v)
                for f in dataclasses.fields(M.Workload)})


# --- 1. the "production" run we will later replay from its trace ----------
wl = synthesize_workload(fitted_params(), jax.random.PRNGKey(31), HORIZON)
wl.arrival = np.floor(wl.arrival)          # integer-time config: exactness
wl.exec_time = np.ceil(wl.exec_time)
wl.read_bytes[:] = 0.0
wl.write_bytes[:] = 0.0

scenario = Scenario(
    name="prod",
    failures=FailureModel(
        p_fail_by_type=(0.3,) * M.N_TASK_TYPES,
        retry=RetryPolicy(max_retries=2, base_s=30.0, mult=2.0, cap_s=240.0),
        resample_service=False))

src = BlockSource(wl)
orig = oneshot_reference(src, scenario=scenario, horizon_s=HORIZON, seed=17)
print(f"original run: {wl.n} pipelines, "
      f"mean wait {orig['summary']['mean_wait_s']:.1f}s, "
      f"p95 wait {orig['summary']['p95_wait_s']:.1f}s")

# --- 2. export the run as spans — the trace a real platform would keep ----
spans = build_spans(orig["records"], name="replay-example")
cut = len(spans) // 3                      # append=True: chunked export
write_spans_jsonl(spans[:cut], SPAN_PATH)
write_spans_jsonl(spans[cut:], SPAN_PATH, append=True)
print(f"exported {len(spans)} spans -> {SPAN_PATH}")

# --- 3. rebuild the workload from the file alone and replay it exactly ----
rsrc = SpanSource(SPAN_PATH)
rscn = rsrc.scenario(backoff=scenario.failures.retry.backoff)
print(f"SpanSource: {rsrc.pipeline_ids.shape[0]} pipelines recovered, "
      f"{rsrc.n_approximate} approximate rows")

replay = oneshot_reference(rsrc, scenario=rscn, horizon_s=HORIZON)
got = attempt_intervals_from_records(rsrc.remap_pipelines(replay["records"]))
want = attempt_intervals(spans)
err = max(max(abs(a0 - b0), abs(a1 - b1))
          for (a0, a1), (b0, b1) in ((got[k], want[k]) for k in want))
print(f"exact replay: {len(want)} attempt intervals, "
      f"max |observed - replayed| = {err}")

# windowed replay is bit-identical to the one-shot replay, too
streamed = stream_simulate(rsrc, scenario=rscn, horizon_s=HORIZON,
                           window_s=HORIZON / 4)
print(f"windowed replay ({streamed.n_windows} windows): "
      f"parity drift vs one-shot = {parity_drift(streamed, replay)}\n")

# --- 4. what-if: same observed demand, different operating point ----------
# The demand (arrivals, services, observed attempt counts) is pinned by
# the trace; schedule and controller are the exchangeable knobs on
# ``SpanSource.scenario``. Here: a quarter of the capacity, with a
# reactive autoscaler allowed to claw some of it back under pressure.
from repro.ops.capacity import static_schedule

lean_caps = np.maximum(1, np.asarray(rsrc.platform.capacities) // 4)
whatif_scn = rsrc.scenario(
    backoff=scenario.failures.retry.backoff,
    schedule=static_schedule(lean_caps),
    controller=ReactiveController(high_watermark=0.2, step=0.5,
                                  max_scale=3.0, interval_s=1800.0),
    horizon_s=HORIZON)
whatif = stream_simulate(rsrc, scenario=whatif_scn, horizon_s=HORIZON,
                         window_s=HORIZON / 4)

base, alt = replay["summary"], whatif.summary
print("what-if on the replayed trace: quarter capacity + autoscaler")
print(f"{'':>24} {'replayed':>10} {'what-if':>10}")
for key in ("mean_wait_s", "p95_wait_s", "p99_wait_s"):
    print(f"{key:>24} {base[key]:>10.1f} {alt[key]:>10.1f}")
print(f"controller actions taken: "
      f"{0 if whatif.ctrl_times is None else len(whatif.ctrl_times)}")
