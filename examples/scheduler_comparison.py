"""Operational strategies (paper §III-B): compare admission policies on the
same congested workload — FIFO vs SJF vs staleness-priority.

Priority scheduling uses the run-time view: each pipeline retrains a
deployed model whose staleness determines its priority ("optimize the
potential improvement of all automated AI pipelines").

  PYTHONPATH=src python examples/scheduler_comparison.py
"""
import jax
import numpy as np

import os
import sys
sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

from benchmarks.common import fitted_params
from repro.core import des
from repro.core import model as M
from repro.core.metrics import DeployedModel
from repro.core.runtime import make_model_fleet
from repro.core.synthesizer import synthesize_workload
from repro.core.trace import flatten_trace

params = fitted_params()
platform = M.PlatformConfig(resources=(
    M.ResourceConfig("compute_cluster", 16),
    M.ResourceConfig("learning_cluster", 6)))
wl = synthesize_workload(params, jax.random.PRNGKey(3),
                         horizon_s=86400.0, platform=platform)

# attach a drifting model to each pipeline; priority = potential improvement
rng = np.random.default_rng(0)
fleet = make_model_fleet(rng, wl.n)
staleness = np.array([m.potential_improvement(7 * 86400.0, 0.3)
                      for m in fleet], np.float32)
wl.priority = staleness

print(f"{'policy':>10} {'mean wait':>10} {'p95 wait':>10} "
      f"{'stale-weighted wait':>20}")
for policy, name in ((des.POLICY_FIFO, "fifo"), (des.POLICY_SJF, "sjf"),
                     (des.POLICY_PRIORITY, "staleness")):
    tr = des.simulate(wl, platform, policy)
    rec = flatten_trace(tr, wl)
    pipe_wait = np.zeros(wl.n)
    np.add.at(pipe_wait, rec.pipeline, rec.wait)
    weighted = float((pipe_wait * staleness).sum() / staleness.sum())
    print(f"{name:>10} {rec.wait.mean():10.1f} "
          f"{np.percentile(rec.wait, 95):10.1f} {weighted:20.1f}")

print("\nStaleness-priority minimizes the staleness-weighted wait — the "
      "paper's 'overall potential improvement' objective — at a modest "
      "mean-wait cost vs SJF.")
