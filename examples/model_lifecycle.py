"""Model lifecycle experiments (paper Fig 7) — the run-time view as a
first-class experiment: a fleet of deployed models drifts, drift triggers
fire retraining pipelines through the platform, completed deployments
restore performance. The whole loop runs INSIDE the DES engines, so a
trigger-policy grid (drift thresholds x cooldowns) lowers to ONE jit+vmap
call on the JAX engine — and traces out the **cost-vs-staleness frontier**:
aggressive triggers buy fresh models with retraining compute, lazy triggers
save compute and eat staleness.

Migration note: this replaces the old serial windowed co-simulation
(``run_feedback_simulation`` is now a thin wrapper over this API):

    # before                                  # now
    run_feedback_simulation(params, seed=0,   ExperimentSpec(
        horizon_s=H, n_models=20,                 name="lifecycle",
        window_s=6*3600,                          horizon_s=H,
        trigger=TriggerRule(                      fleet=FleetSpec(n_models=20),
            drift_threshold=0.08))                trigger=TriggerSpec(
                                                      drift_threshold=0.08,
                                                      interval_s=6*3600),
                                                  engine="jax")

  PYTHONPATH=src python examples/model_lifecycle.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                "..")))

from benchmarks.common import fitted_params
from repro.core.experiment import ExperimentSpec, Sweep
from repro.core.runtime import FleetSpec, TriggerSpec

params = fitted_params()
HORIZON = 86400.0

base = ExperimentSpec(
    name="lifecycle",
    horizon_s=HORIZON,
    seed=7,
    engine="jax",
    # accelerated aging so a 1-day horizon sees the whole loop many times
    fleet=FleetSpec(n_models=8, drift_scale=60.0),
    trigger=TriggerSpec(interval_s=3600.0, obs_noise=0.005,
                        cooldown_s=4 * 3600.0),
)

# the lifecycle-policy grid: every point is a (threshold, cooldown) trigger
# policy over the same drifting fleet — ONE jit+vmap simulate_ensemble call
sweep = Sweep(base, {
    "trigger:drift_threshold": [0.02, 0.04, 0.08, 0.16],
    "trigger:cooldown_s": [2 * 3600.0, 8 * 3600.0],
})
results = sweep.run(params)

print(f"{'policy':<46}{'retrains':>9}{'retrain nh':>11}"
      f"{'mean stale':>11}{'final perf':>11}")
frontier = []
for r in results:
    lc = r.summary["lifecycle"]
    label = r.experiment.name.split("/", 1)[-1]
    nh = lc["retrain_node_seconds"] / 3600.0
    print(f"{label:<46}{lc['n_retrained']:>9d}{nh:>11.2f}"
          f"{lc['mean_staleness']:>11.4f}"
          f"{lc['final_mean_performance']:>11.4f}")
    frontier.append((nh, lc["mean_staleness"], label))

# the frontier: policies no other policy beats on BOTH axes
frontier.sort()
print("\ncost-vs-staleness frontier (non-dominated trigger policies):")
best = np.inf
for nh, stale, label in frontier:
    if stale < best:
        best = stale
        print(f"  {nh:8.2f} retrain node-hours -> mean staleness {stale:.4f}"
              f"   [{label}]")

# drill into one run: the engine-recorded lifecycle action timeline
one = results[5]
if one.lifecycle is not None:
    lc = one.lifecycle
    print(f"\n{one.experiment.name}: {lc.n_triggered} triggers, "
          f"{lc.n_retrained} redeploys over {HORIZON / 86400.0:.0f} day(s)")
    for t, m in list(zip(lc.redeploy_times, lc.redeploy_models))[:5]:
        print(f"  t={t / 3600.0:7.1f}h  model {int(m):2d} redeployed")
