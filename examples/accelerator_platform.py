"""The trace link (DESIGN.md §2): simulate an accelerator-cluster AI platform
whose training-task durations come from the ROOFLINE COST MODEL of the
compiled Level-1 stack — PipeSim scheduling the very architectures this
repo trains.

Requires dry-run artifacts (run ``python -m repro.launch.dryrun --all
--mesh single`` first).

  PYTHONPATH=src python examples/accelerator_platform.py
"""
import jax
import numpy as np

from repro.core import costmodel, des
from repro.core import model as M
from repro.core.stats import Dist

catalog = costmodel.accelerator_workload_catalog(n_steps=2000)
if not catalog:
    raise SystemExit("no dry-run artifacts found — run repro.launch.dryrun")

print("roofline-grounded train-task medians (2000 steps):")
for arch, dist in sorted(catalog.items()):
    med = float(np.median(np.asarray(dist.sample(jax.random.PRNGKey(0),
                                                 (2000,)))))
    print(f"  {arch:28s} {med / 3600.0:8.2f} h")

# build a platform workload: retraining jobs for a fleet of these archs
archs = sorted(catalog)
rng = np.random.default_rng(1)
n = 300
arrival = np.sort(rng.uniform(0, 7 * 86400.0, n))
pick = rng.integers(0, len(archs), n)
key = jax.random.PRNGKey(2)
dur = np.array([float(catalog[archs[p]].sample(
    jax.random.fold_in(key, i), ())) for i, p in enumerate(pick)])

tt = np.full((n, 1), M.TRAIN, np.int32)
wl = M.Workload(
    arrival=arrival, n_tasks=np.ones(n, np.int32), task_type=tt,
    task_res=np.ones((n, 1), np.int32),  # learning cluster
    exec_time=dur[:, None], read_bytes=np.zeros((n, 1)),
    write_bytes=np.zeros((n, 1)), framework=pick.astype(np.int32),
    priority=np.zeros(n, np.float32), model_perf=np.zeros(n, np.float32),
    model_size=np.zeros(n, np.float32), model_clever=np.zeros(n, np.float32))

for n_pods in (2, 4, 8):
    plat = M.PlatformConfig(resources=(
        M.ResourceConfig("compute", 1),
        M.ResourceConfig("tpu_pods", n_pods)))
    tr = des.simulate(wl, plat)
    wait = tr.wait[:, 0]
    print(f"pods={n_pods}: mean queue wait {wait.mean() / 3600.0:6.1f} h, "
          f"p95 {np.percentile(wait, 95) / 3600.0:6.1f} h")

print("\nThis is the paper's 'link to the real system': pod-count planning "
      "for retraining fleets, grounded in compiled-artifact rooflines.")
