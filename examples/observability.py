"""The in-simulation telemetry plane, end to end: probe a closed-loop
lifecycle experiment, read the named channel timelines, and export the run
as an OTel-style span tree you can open in a real trace viewer.

One ``ProbeSpec`` on the experiment turns on in-loop sampling: both engines
record queue depth, busy slots, effective capacity, controller delta, and
fleet perf/staleness at a fixed tick grid — inside the simulation loop, with
bit-identical buffers on the numpy and JAX engines (the parity gate in
``benchmarks/obs_bench.py`` enforces exactly that). The span export turns
the same run's task records + engine-recorded actions into
``artifacts/observability_trace.json`` — drag it onto
https://ui.perfetto.dev (or ``chrome://tracing``) to scrub through the
simulated platform like a production trace.

  PYTHONPATH=src python examples/observability.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                "..")))

from benchmarks.common import ART, fitted_params
from repro.core.experiment import ExperimentSpec, run_experiment
from repro.core.runtime import FleetSpec, TriggerSpec
from repro.core.trace import flatten_trace
from repro.obs import ProbeSpec, build_spans, write_chrome_trace, \
    write_spans_jsonl
from repro.ops import ReactiveController

params = fitted_params()
HORIZON = 86400.0

spec = ExperimentSpec(
    name="observability",
    horizon_s=HORIZON,
    seed=3,
    engine="numpy",
    fleet=FleetSpec(n_models=6, drift_scale=60.0),
    trigger=TriggerSpec(interval_s=3600.0, obs_noise=0.005,
                        cooldown_s=4 * 3600.0, drift_threshold=0.06),
    probe=ProbeSpec(interval_s=1800.0),        # sample every 30 min
).with_(controller=ReactiveController(high_watermark=0.3, step=0.5,
                                      max_scale=3.0, interval_s=3600.0))

res = run_experiment(spec, params)

# --- 1. the probe timeline: named channels at the probe's tick grid
tl = res.timeline
s = tl.sampled
print(f"probe: {int(s.sum())}/{tl.times.shape[0]} ticks sampled, "
      f"channels = {list(tl.channels)}\n")
print(f"{'t [h]':>7} {'qlen:cc':>8} {'busy:cc':>8} {'cap:cc':>7} "
      f"{'delta:cc':>9} {'min perf':>9} {'max stale[h]':>13}")
for i in np.nonzero(s)[0][::4]:
    print(f"{tl.times[i] / 3600.0:>7.1f} "
          f"{tl.channel('qlen:compute_cluster')[i]:>8.0f} "
          f"{tl.channel('busy:compute_cluster')[i]:>8.0f} "
          f"{tl.channel('cap:compute_cluster')[i]:>7.0f} "
          f"{tl.channel('ctrl_delta:compute_cluster')[i]:>9.0f} "
          f"{tl.channel('fleet_min_perf')[i]:>9.4f} "
          f"{tl.channel('fleet_max_staleness')[i] / 3600.0:>13.2f}")

# --- 2. span export: the run as a distributed-tracing tree
# (engine-level runs can also pass the SimTrace to build_spans, attaching
# controller scale / lifecycle trigger actions as root-span events — see
# benchmarks/obs_bench.py)
rec = res.records
spans = build_spans(rec, name=spec.name)
kinds = {}
for sp in spans:
    kinds[sp["kind"]] = kinds.get(sp["kind"], 0) + 1
print(f"\nspan tree: {kinds}")

os.makedirs(ART, exist_ok=True)
jsonl = os.path.join(ART, "observability_spans.jsonl")
chrome = os.path.join(ART, "observability_trace.json")
write_spans_jsonl(spans, jsonl)
write_chrome_trace(spans, chrome)
print(f"wrote {jsonl}")
print(f"wrote {chrome}")
print("open the trace: https://ui.perfetto.dev  (or chrome://tracing) and "
      "load observability_trace.json")
